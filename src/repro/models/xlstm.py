"""xLSTM blocks (arXiv:2405.04517): mLSTM (matrix memory, parallelizable)
and sLSTM (scalar memory, strictly recurrent) with exponential gating and
max-stabilizers.  Both expose full-sequence forward (lax.scan over time)
and single-token decode with explicit state caches.

xlstm-125m stacks alternating mLSTM/sLSTM blocks; neither uses attention,
so the paper's technique is inapplicable here (DESIGN.md §5) — the arch is
implemented without it and exercises the framework's attention-free path
(including long_500k decode, which is O(1) per token in state size).
"""

from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.quant import get_quant
from .layers import dense_init, rms_norm


class MLSTMState(NamedTuple):
    c: jax.Array  # [B, H, P, P] matrix memory
    n: jax.Array  # [B, H, P] normalizer
    m: jax.Array  # [B, H] stabilizer


class SLSTMState(NamedTuple):
    c: jax.Array  # [B, D]
    n: jax.Array  # [B, D]
    m: jax.Array  # [B, D]
    h: jax.Array  # [B, D] recurrent output


# -- mLSTM ---------------------------------------------------------------------

def mlstm_params(key, cfg: ModelConfig, dtype) -> dict:
    d = cfg.d_model
    h = cfg.num_heads
    p = d // h
    keys = jax.random.split(key, 6)
    return {
        "wq": dense_init(keys[0], d, d, dtype),
        "wk": dense_init(keys[1], d, d, dtype),
        "wv": dense_init(keys[2], d, d, dtype),
        "wi": dense_init(keys[3], d, h, dtype),  # input gate (exp)
        "wf": dense_init(keys[4], d, h, dtype),  # forget gate
        "wo": dense_init(keys[5], d, d, dtype),
        "bi": jnp.zeros((h,), dtype),
        "bf": jnp.ones((h,), dtype),  # bias toward remembering
        "norm_scale": jnp.ones((d,), dtype),
    }


def _mlstm_step(state: MLSTMState, inp, head_dim: int):
    q, k, v, i_raw, f_raw = inp  # q/k/v: [B,H,P]; gates: [B,H]
    logf = -jax.nn.softplus(-f_raw)  # log sigmoid(f)
    m_new = jnp.maximum(logf + state.m, i_raw)
    i_g = jnp.exp(i_raw - m_new)
    f_g = jnp.exp(logf + state.m - m_new)
    k_s = k / jnp.sqrt(jnp.asarray(head_dim, jnp.float32))
    c_new = f_g[..., None, None] * state.c + i_g[..., None, None] * (
        v[..., :, None] * k_s[..., None, :]
    )
    n_new = f_g[..., None] * state.n + i_g[..., None] * k_s
    num = jnp.einsum("bhpq,bhq->bhp", c_new, q)
    den = jnp.maximum(jnp.abs(jnp.einsum("bhp,bhp->bh", n_new, q)), 1.0)
    h_out = num / den[..., None]
    return MLSTMState(c_new, n_new, m_new), h_out


def mlstm_forward(x: jax.Array, params: dict, cfg: ModelConfig) -> jax.Array:
    b, s, d = x.shape
    nh = cfg.num_heads
    p = d // nh
    qd = lambda w: get_quant(cfg).dot(x, params[w], "xlstm")  # noqa: E731
    q = qd("wq").reshape(b, s, nh, p).astype(jnp.float32)
    k = qd("wk").reshape(b, s, nh, p).astype(jnp.float32)
    v = qd("wv").reshape(b, s, nh, p).astype(jnp.float32)
    i_raw = (qd("wi") + params["bi"]).astype(jnp.float32)  # [B,S,H]
    f_raw = (qd("wf") + params["bf"]).astype(jnp.float32)

    init = MLSTMState(
        c=jnp.zeros((b, nh, p, p), jnp.float32),
        n=jnp.zeros((b, nh, p), jnp.float32),
        m=jnp.full((b, nh), -1e30, jnp.float32),
    )
    xs = (
        jnp.moveaxis(q, 1, 0), jnp.moveaxis(k, 1, 0), jnp.moveaxis(v, 1, 0),
        jnp.moveaxis(i_raw, 1, 0), jnp.moveaxis(f_raw, 1, 0),
    )
    _, hs = jax.lax.scan(lambda st, inp: _mlstm_step(st, inp, p), init, xs)
    h = jnp.moveaxis(hs, 0, 1).reshape(b, s, d).astype(x.dtype)
    h = rms_norm(h, params["norm_scale"])
    return get_quant(cfg).dot(h, params["wo"], "xlstm")


def mlstm_decode(x, params, cfg: ModelConfig, state: MLSTMState):
    b, _, d = x.shape
    nh = cfg.num_heads
    p = d // nh
    qd = lambda w: get_quant(cfg).dot(x, params[w], "xlstm")  # noqa: E731
    q = qd("wq")[:, 0].reshape(b, nh, p).astype(jnp.float32)
    k = qd("wk")[:, 0].reshape(b, nh, p).astype(jnp.float32)
    v = qd("wv")[:, 0].reshape(b, nh, p).astype(jnp.float32)
    i_raw = (qd("wi") + params["bi"])[:, 0].astype(jnp.float32)
    f_raw = (qd("wf") + params["bf"])[:, 0].astype(jnp.float32)
    new_state, h = _mlstm_step(state, (q, k, v, i_raw, f_raw), p)
    h = h.reshape(b, 1, d).astype(x.dtype)
    h = rms_norm(h, params["norm_scale"])
    return get_quant(cfg).dot(h, params["wo"], "xlstm"), new_state


def init_mlstm_state(cfg: ModelConfig, batch: int) -> MLSTMState:
    nh = cfg.num_heads
    p = cfg.d_model // nh
    return MLSTMState(
        c=jnp.zeros((batch, nh, p, p), jnp.float32),
        n=jnp.zeros((batch, nh, p), jnp.float32),
        m=jnp.full((batch, nh), -1e30, jnp.float32),
    )


# -- sLSTM ---------------------------------------------------------------------

def slstm_params(key, cfg: ModelConfig, dtype) -> dict:
    d = cfg.d_model
    keys = jax.random.split(key, 9)
    p = {"norm_scale": jnp.ones((d,), dtype)}
    for idx, gate in enumerate(("i", "f", "z", "o")):
        p[f"w{gate}"] = dense_init(keys[idx], d, d, dtype)
        p[f"r{gate}"] = dense_init(keys[4 + idx], d, d, dtype)  # recurrent
        p[f"b{gate}"] = jnp.zeros((d,), dtype)
    return p


def _slstm_step(params, state: SLSTMState, x_t: jax.Array, quant=None):
    """x_t: [B, D] (pre-activations use recurrent h)."""
    h_prev = state.h
    dot = (lambda a, w: quant.dot(a, w, "xlstm")) if quant else (lambda a, w: a @ w)
    pre = lambda g: (  # noqa: E731
        dot(x_t, params[f"w{g}"])
        + dot(h_prev.astype(x_t.dtype), params[f"r{g}"])
        + params[f"b{g}"]
    ).astype(jnp.float32)
    i_raw, f_raw, z_raw, o_raw = pre("i"), pre("f"), pre("z"), pre("o")
    logf = -jax.nn.softplus(-f_raw)
    m_new = jnp.maximum(logf + state.m, i_raw)
    i_g = jnp.exp(i_raw - m_new)
    f_g = jnp.exp(logf + state.m - m_new)
    c_new = f_g * state.c + i_g * jnp.tanh(z_raw)
    n_new = f_g * state.n + i_g
    h_new = jax.nn.sigmoid(o_raw) * c_new / jnp.maximum(n_new, 1e-6)
    return SLSTMState(c_new, n_new, m_new, h_new), h_new


def slstm_forward(x: jax.Array, params: dict, cfg: ModelConfig) -> jax.Array:
    b, s, d = x.shape
    init = init_slstm_state(cfg, b)
    quant = get_quant(cfg)
    _, hs = jax.lax.scan(
        lambda st, xt: _slstm_step(params, st, xt, quant), init, jnp.moveaxis(x, 1, 0)
    )
    h = jnp.moveaxis(hs, 0, 1).astype(x.dtype)
    h = rms_norm(h, params["norm_scale"])
    return h


def slstm_decode(x, params, cfg: ModelConfig, state: SLSTMState):
    new_state, h = _slstm_step(params, state, x[:, 0], get_quant(cfg))
    h = h[:, None, :].astype(x.dtype)
    return rms_norm(h, params["norm_scale"]), new_state


def init_slstm_state(cfg: ModelConfig, batch: int) -> SLSTMState:
    d = cfg.d_model
    z = jnp.zeros((batch, d), jnp.float32)
    return SLSTMState(c=z, n=z, m=jnp.full((batch, d), -1e30, jnp.float32), h=z)
