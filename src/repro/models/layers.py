"""Shared neural-net building blocks (pure functions over param pytrees)."""

from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.quant import Quant

_FP = Quant()  # no-op policy for call sites without a config


# -- initializers ---------------------------------------------------------------

def dense_init(key, in_dim: int, out_dim: int, dtype) -> jax.Array:
    scale = 1.0 / np.sqrt(in_dim)
    return (jax.random.normal(key, (in_dim, out_dim), jnp.float32) * scale).astype(dtype)


def embed_init(key, vocab: int, dim: int, dtype) -> jax.Array:
    return (jax.random.normal(key, (vocab, dim), jnp.float32) * 0.02).astype(dtype)


# -- norms -----------------------------------------------------------------------

def rms_norm(x: jax.Array, weight: Optional[jax.Array], eps: float = 1e-6) -> jax.Array:
    dtype = x.dtype
    x = x.astype(jnp.float32)
    x = x * jax.lax.rsqrt(jnp.mean(x * x, axis=-1, keepdims=True) + eps)
    if weight is not None:
        x = x * weight.astype(jnp.float32)
    return x.astype(dtype)


def layer_norm(
    x: jax.Array,
    weight: Optional[jax.Array],
    bias: Optional[jax.Array],
    eps: float = 1e-5,
) -> jax.Array:
    dtype = x.dtype
    x = x.astype(jnp.float32)
    mu = jnp.mean(x, axis=-1, keepdims=True)
    var = jnp.mean(jnp.square(x - mu), axis=-1, keepdims=True)
    x = (x - mu) * jax.lax.rsqrt(var + eps)
    if weight is not None:
        x = x * weight.astype(jnp.float32)
    if bias is not None:
        x = x + bias.astype(jnp.float32)
    return x.astype(dtype)


def apply_norm(x: jax.Array, params: Optional[dict], norm_type: str) -> jax.Array:
    if norm_type == "rmsnorm":
        return rms_norm(x, params["scale"] if params else None)
    if norm_type == "layernorm":
        return layer_norm(x, params["scale"], params["bias"])
    if norm_type == "non_parametric":  # OLMo: LN without learnable params
        return layer_norm(x, None, None)
    raise ValueError(norm_type)


def norm_params(key, d: int, norm_type: str, dtype) -> Optional[dict]:
    if norm_type == "rmsnorm":
        return {"scale": jnp.ones((d,), dtype)}
    if norm_type == "layernorm":
        return {"scale": jnp.ones((d,), dtype), "bias": jnp.zeros((d,), dtype)}
    if norm_type == "non_parametric":
        return None
    raise ValueError(norm_type)


# -- rotary embeddings -------------------------------------------------------------

def rope_frequencies(head_dim: int, theta: float) -> np.ndarray:
    return 1.0 / (theta ** (np.arange(0, head_dim, 2, dtype=np.float64) / head_dim))


def apply_rope(
    x: jax.Array,  # [B, S, H, d]
    positions: jax.Array,  # [B, S]
    theta: float = 10000.0,
) -> jax.Array:
    d = x.shape[-1]
    freqs = jnp.asarray(rope_frequencies(d, theta), jnp.float32)  # [d/2]
    angles = positions[..., None].astype(jnp.float32) * freqs  # [B, S, d/2]
    cos = jnp.cos(angles)[:, :, None, :]
    sin = jnp.sin(angles)[:, :, None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


def apply_mrope(
    x: jax.Array,  # [B, S, H, d]
    positions: jax.Array,  # [B, S, 3] (t, h, w) — qwen2-vl M-RoPE
    sections: tuple[int, int, int],
    theta: float = 1_000_000.0,
) -> jax.Array:
    """Multimodal RoPE: the head_dim/2 frequency slots are partitioned into
    (temporal, height, width) sections, each rotated by its own position id.
    For pure-text tokens all three ids coincide and M-RoPE reduces to RoPE."""
    d = x.shape[-1]
    half = d // 2
    assert sum(sections) == half, (sections, half)
    freqs = jnp.asarray(rope_frequencies(d, theta), jnp.float32)  # [half]
    # Build a per-slot position by selecting the section's position id.
    sec_id = jnp.asarray(
        np.repeat(np.arange(3), np.asarray(sections)), jnp.int32
    )  # [half]
    pos = positions.astype(jnp.float32)[:, :, sec_id]  # [B, S, half]
    angles = pos * freqs
    cos = jnp.cos(angles)[:, :, None, :]
    sin = jnp.sin(angles)[:, :, None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


# -- MLPs --------------------------------------------------------------------------

def mlp_params(key, d: int, d_ff: int, mlp_type: str, dtype) -> dict:
    keys = jax.random.split(key, 3)
    if mlp_type == "swiglu":
        return {
            "gate": dense_init(keys[0], d, d_ff, dtype),
            "up": dense_init(keys[1], d, d_ff, dtype),
            "down": dense_init(keys[2], d_ff, d, dtype),
        }
    return {
        "up": dense_init(keys[0], d, d_ff, dtype),
        "down": dense_init(keys[1], d_ff, d, dtype),
    }


def mlp_forward(
    x: jax.Array, params: dict, mlp_type: str, quant: Quant = _FP
) -> jax.Array:
    dot = lambda a, w: quant.dot(a, w, "mlp")  # noqa: E731
    if mlp_type == "swiglu":
        h = jax.nn.silu(dot(x, params["gate"])) * dot(x, params["up"])
    elif mlp_type == "squared_relu":  # nemotron-4
        h = jnp.square(jax.nn.relu(dot(x, params["up"])))
    elif mlp_type == "gelu":
        h = jax.nn.gelu(dot(x, params["up"]))
    else:
        raise ValueError(mlp_type)
    return dot(h, params["down"])
