"""Mixture-of-Experts layer: top-k token-choice routing with capacity and
**explicit expert parallelism via shard_map**.

Why shard_map and not plain pjit: the dispatch scatter/gather pattern of
token-choice MoE defeats GSPMD's scatter partitioner — it replicates the
[T*k, d] token copies at global size (we measured ~128 GiB/device buffers
on the qwen3-235B dry-run).  Under shard_map every rank works on its local
tokens only and the layout is explicit:

  * tokens are sharded over the data axes and *replicated* over 'model';
  * expert weights are sharded over 'model' (num_experts / 16 per rank);
  * each rank routes its local tokens, keeps only pairs that hit its local
    experts, and builds a capacity-bounded [E_local, C, d] buffer via an
    index-inversion gather (token_for_slot) — the [T*k, d] all-pairs tensor
    never exists;
  * partial outputs are combined with one psum over 'model' — the same
    collective a Megatron row-parallel MLP pays, and the EP analogue of
    the all-to-all+combine in DeepSpeed-MoE.

Capacity dropping (capacity_factor, default 1.25) happens per rank over
its local token pool, matching per-device capacity semantics of real EP
systems.  No [T, E, C] one-hot is ever built.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.configs.base import ModelConfig
from repro.dist.collectives import _ambient_axis_names
from repro.quant import get_quant
from .layers import dense_init, mlp_forward

DATA_AXES = ("pod", "data")


def moe_params(key, cfg: ModelConfig, dtype) -> dict:
    moe = cfg.moe
    d, e, ff = cfg.d_model, moe.num_experts, moe.d_ff_expert
    keys = jax.random.split(key, 4)

    def experts(k, in_d, out_d):
        ks = jax.random.split(k, e)
        return jax.vmap(lambda kk: dense_init(kk, in_d, out_d, dtype))(ks)

    return {
        "router": dense_init(keys[0], d, e, jnp.float32),
        "gate": experts(keys[1], d, ff),
        "up": experts(keys[2], d, ff),
        "down": experts(keys[3], ff, d),
    }


def _moe_block(x, router, gate, up, down, cfg: ModelConfig,
               expert_offset, total_tokens_hint=None, dropless=False):
    """MoE over a local token block with a local expert slice.

    x: [B_loc, S, d]; gate/up/down: [E_loc, ...]; expert_offset: first
    global expert id owned by this rank.  Returns this rank's partial
    output (sum over ranks = full MoE output).

    ``dropless`` sets capacity to the whole token pool, so no copy is ever
    dropped and each token's output depends only on its own routing — the
    decode/verify contract (see ``moe_forward``).
    """
    moe = cfg.moe
    b, s, d = x.shape
    t = b * s
    k = moe.top_k
    e = moe.num_experts
    e_loc = gate.shape[0]
    capacity = t if dropless else max(int(t * k * moe.capacity_factor / e), 1)

    xf = x.reshape(t, d)
    logits = xf.astype(jnp.float32) @ router  # router is replicated
    probs = jax.nn.softmax(logits, axis=-1)
    top_p, top_e = jax.lax.top_k(probs, k)  # [T, k]
    top_p = top_p / jnp.sum(top_p, axis=-1, keepdims=True)

    flat_e = top_e.reshape(-1)  # [T*k] int
    flat_p = top_p.reshape(-1)
    flat_t = jnp.repeat(jnp.arange(t), k)
    order = jnp.argsort(flat_e)
    e_s, p_s, t_s = flat_e[order], flat_p[order], flat_t[order]
    counts = jnp.bincount(e_s, length=e)
    starts = jnp.cumsum(counts) - counts
    pos = jnp.arange(t * k) - starts[e_s]

    local_e = e_s - expert_offset
    keep = (pos < capacity) & (local_e >= 0) & (local_e < e_loc)
    # Index inversion: which token fills (local_expert, slot)?  Index
    # arrays are [E_loc, C] int32 — tiny; the [T*k, d] all-pairs tensor
    # never materializes.
    slot_flat = jnp.where(keep, local_e * capacity + pos, e_loc * capacity)
    token_for_slot = (
        jnp.full((e_loc * capacity + 1,), t, jnp.int32)
        .at[slot_flat]
        .set(t_s.astype(jnp.int32), mode="drop")[: e_loc * capacity]
    )
    weight_for_slot = (
        jnp.zeros((e_loc * capacity + 1,), jnp.float32)
        .at[slot_flat]
        .set(p_s, mode="drop")[: e_loc * capacity]
    )

    # Gather tokens into the expert buffer (sentinel t -> zero row).
    xf_pad = jnp.concatenate([xf, jnp.zeros((1, d), xf.dtype)], axis=0)
    buf = xf_pad[token_for_slot].reshape(e_loc, capacity, d)

    # Expert matmuls via the quant policy: [E_loc, C, d] x [E_loc, d, f]
    # batched dots run int8 with int32 accumulation when cfg.quant covers
    # the "moe" class (per-row token scales, per-expert-channel weight
    # scales); otherwise the plain einsum.
    quant = get_quant(cfg)
    h = jax.nn.silu(quant.dot_batched(buf, gate, "moe"))
    h = h * quant.dot_batched(buf, up, "moe")
    out_buf = quant.dot_batched(h, down, "moe")  # [E_loc, C, d]

    # Combine: weight rows and scatter-add back to tokens (one scatter of
    # [E_loc*C, d]; sentinel rows drop).
    weighted = out_buf.reshape(e_loc * capacity, d) * weight_for_slot[:, None].astype(
        x.dtype
    )
    y = (
        jnp.zeros((t + 1, d), x.dtype)
        .at[token_for_slot]
        .add(weighted, mode="drop")[:t]
    )
    return y.reshape(b, s, d)


def moe_forward(
    x: jax.Array, params: dict, cfg: ModelConfig, dropless: bool = False
) -> jax.Array:
    """x: [B, S, d] -> [B, S, d].

    ``dropless=True`` is the decode-side mode (single-token decode and the
    speculative verify pass): expert capacity equals the token pool, so no
    token is ever dropped and routing is per-token independent.  That
    independence is what makes a token's logits identical whether it runs
    in a [B, 1] decode step or a [B, K+1] verify chunk, whatever its
    lane-mates are — the engine's token-equivalence contract for MoE.
    Train/prefill keep the capacity-bounded EP semantics (the drop is the
    compute-efficiency feature there).
    """
    moe = cfg.moe
    names = _ambient_axis_names()
    if "model" not in names:
        # Single-shard path (unit tests / CPU smoke): all experts local.
        return _moe_block(
            x, params["router"], params["gate"], params["up"], params["down"],
            cfg, expert_offset=0, dropless=dropless,
        ).astype(x.dtype)

    daxes = tuple(a for a in DATA_AXES if a in names)
    e = moe.num_experts
    model_size = 1
    mesh = jax.sharding.get_abstract_mesh()
    model_size = mesh.shape["model"]
    assert e % model_size == 0, (e, model_size)
    e_loc = e // model_size

    # FSDP/ZeRO-3 for the expert weights: at rest each leaf is sharded over
    # 'model' (experts, EP) *and* 'data' (the ff dim) — 1/256th per device —
    # and all-gathered over 'data' just-in-time inside the block (the
    # gather's transpose is the reduce-scatter of the expert grads).
    fsdp = "data" in names and (moe.d_ff_expert % mesh.shape["data"] == 0)

    def block(x_b, router_b, gate_b, up_b, down_b):
        rank = jax.lax.axis_index("model")
        if fsdp:
            gate_b = jax.lax.all_gather(gate_b, "data", axis=2, tiled=True)
            up_b = jax.lax.all_gather(up_b, "data", axis=2, tiled=True)
            down_b = jax.lax.all_gather(down_b, "data", axis=1, tiled=True)
        y = _moe_block(
            x_b, router_b, gate_b, up_b, down_b, cfg,
            expert_offset=rank * e_loc, dropless=dropless,
        )
        # Sum partial expert contributions across EP ranks (row-parallel
        # combine; tokens are replicated over 'model').
        return jax.lax.psum(y, "model")

    ffd = "data" if fsdp else None
    sm = jax.shard_map(
        block,
        in_specs=(
            P(daxes, None, None),       # x: tokens over data, repl. over model
            P(None, None),              # router: replicated
            P("model", None, ffd),      # experts: EP (+ ZeRO-3 over ff)
            P("model", None, ffd),
            P("model", ffd, None),
        ),
        out_specs=P(daxes, None, None),
    )
    return sm(x, params["router"], params["gate"], params["up"], params["down"]).astype(
        x.dtype
    )


def moe_with_dense_residual(
    x: jax.Array, params: dict, dense_params: dict, cfg: ModelConfig
) -> jax.Array:
    """Arctic: dense FFN running in parallel with the MoE branch."""
    return moe_forward(x, params, cfg) + mlp_forward(
        x, dense_params, cfg.mlp_type, get_quant(cfg)
    )
