from .model import (  # noqa: F401
    decode_step,
    forward,
    init_cache,
    init_params,
    insert_cache,
    lm_loss,
    param_shapes,
    prefill_step,
    rollback_cache,
    verify_step,
)
