from .model import decode_step, forward, init_cache, init_params, lm_loss, param_shapes  # noqa: F401
