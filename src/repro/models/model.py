"""Model assembly: init / forward / decode for every architecture family.

Families:
  dense | moe | vlm | encoder — transformer stacks (scan-over-layers, remat)
  hybrid — zamba2: Mamba2 backbone + one *shared* attention(+MLP) block
           applied every ``cfg.attn_every`` layers (weights shared, KV caches
           per application)
  ssm    — xlstm: alternating mLSTM / sLSTM blocks (attention-free)

All params are plain nested dicts; layer params are stacked along a leading
axis and consumed by ``jax.lax.scan`` so the per-layer HLO is compiled once
(critical for 94-layer dry-run compiles).
"""

from __future__ import annotations

import functools
from typing import Any, NamedTuple, Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.dist.collectives import constrain
from repro.quant import get_quant
from .attention import (
    KVCache,
    QuantKVCache,
    attention_forward,
    attention_params,
    decode_attention,
    init_kv_cache,
    prefill_attention,
    verify_attention,
)
from .layers import apply_norm, embed_init, mlp_forward, mlp_params, norm_params
from .moe import moe_forward, moe_params
from .ssm import MambaCache, init_mamba_cache, mamba_decode, mamba_forward, mamba_params
from .xlstm import (
    MLSTMState,
    SLSTMState,
    init_mlstm_state,
    init_slstm_state,
    mlstm_decode,
    mlstm_forward,
    mlstm_params,
    slstm_decode,
    slstm_forward,
    slstm_params,
)

# ---------------------------------------------------------------------------
# init
# ---------------------------------------------------------------------------


def _transformer_layer_params(key, cfg: ModelConfig, dtype) -> dict:
    keys = jax.random.split(key, 5)
    p = {
        "attn_norm": norm_params(keys[0], cfg.d_model, cfg.norm_type, dtype),
        "attn": attention_params(keys[1], cfg, dtype),
        "mlp_norm": norm_params(keys[2], cfg.d_model, cfg.norm_type, dtype),
    }
    if cfg.moe is not None:
        p["moe"] = moe_params(keys[3], cfg, dtype)
        if cfg.moe.dense_residual:
            p["dense_mlp"] = mlp_params(keys[4], cfg.d_model, cfg.d_ff, cfg.mlp_type, dtype)
    else:
        p["mlp"] = mlp_params(keys[3], cfg.d_model, cfg.d_ff, cfg.mlp_type, dtype)
    return p


def init_params(cfg: ModelConfig, key: jax.Array) -> dict:
    dtype = cfg.activation_dtype
    keys = jax.random.split(key, 8)
    params: dict[str, Any] = {}
    params["embed"] = embed_init(keys[0], cfg.vocab_size, cfg.d_model, dtype)
    params["final_norm"] = norm_params(keys[1], cfg.d_model, cfg.norm_type, dtype)
    if not cfg.tie_embeddings:
        params["lm_head"] = embed_init(keys[2], cfg.vocab_size, cfg.d_model, dtype).T

    if cfg.family in ("dense", "moe", "vlm", "encoder"):
        layer_keys = jax.random.split(keys[3], cfg.num_layers)
        params["layers"] = jax.vmap(
            lambda k: _transformer_layer_params(k, cfg, dtype)
        )(layer_keys)
    elif cfg.family == "hybrid":
        layer_keys = jax.random.split(keys[3], cfg.num_layers)
        params["mamba_layers"] = jax.vmap(
            lambda k: {
                "norm": norm_params(None, cfg.d_model, cfg.norm_type, dtype),
                "mamba": mamba_params(k, cfg, dtype),
            }
        )(layer_keys)
        params["shared_attn"] = _transformer_layer_params(keys[4], cfg, dtype)
    elif cfg.family == "ssm":
        n_blocks = cfg.num_layers // 2  # one (mLSTM, sLSTM) pair per block
        block_keys = jax.random.split(keys[3], n_blocks)
        params["blocks"] = jax.vmap(
            lambda k: {
                "mlstm_norm": norm_params(None, cfg.d_model, cfg.norm_type, dtype),
                "mlstm": mlstm_params(jax.random.fold_in(k, 0), cfg, dtype),
                "slstm_norm": norm_params(None, cfg.d_model, cfg.norm_type, dtype),
                "slstm": slstm_params(jax.random.fold_in(k, 1), cfg, dtype),
            }
        )(block_keys)
    else:
        raise ValueError(cfg.family)
    return params


def param_shapes(cfg: ModelConfig) -> dict:
    """Abstract params (ShapeDtypeStructs) — no allocation; dry-run input."""
    return jax.eval_shape(lambda k: init_params(cfg, k), jax.random.PRNGKey(0))


# ---------------------------------------------------------------------------
# forward
# ---------------------------------------------------------------------------


def _default_positions(cfg: ModelConfig, batch: int, seq: int, offset=0):
    pos = offset + jnp.arange(seq, dtype=jnp.int32)[None, :]
    pos = jnp.broadcast_to(pos, (batch, seq))
    if cfg.mrope_sections is not None:
        pos = jnp.broadcast_to(pos[..., None], (batch, seq, 3))
    return pos


def _sp(x, cfg: ModelConfig):
    """Sequence-parallel residual sharding (Megatron-SP): the layer-scan
    carry — which remat checkpoints per layer — lives sharded over
    (data x model) instead of (data x replicated).  GSPMD inserts the
    all-gather before attention/MLP and the reduce-scatter after, halving
    TP collective volume and dividing checkpointed activation memory by
    the model-axis size.  No-op without an ambient mesh.

    Under dp_only the batch dim spans every axis and the carry is simply
    batch-sharded."""
    if cfg.parallelism == "dp_only":
        return constrain(x, ("pod", "data", "model"), None, None)
    return constrain(x, ("pod", "data"), "model", None)


def _transformer_block(x, layer, cfg: ModelConfig, positions, kv=None, start=0):
    """One transformer block.  With ``kv`` (a per-layer KVCache) the
    attention sub-block runs the chunked-prefill path — K/V written into
    the cache at [start, start+S) — and the updated cache is returned
    alongside the activations; without it, plain full-sequence attention.
    Both paths share the same MLP/norm code and attention dispatch, so
    prefill-into-cache and training forward are numerically identical."""
    x = _sp(x, cfg)
    h = apply_norm(x, layer["attn_norm"], cfg.norm_type)
    if kv is None:
        a = attention_forward(h, layer["attn"], cfg, positions)
    else:
        a, kv = prefill_attention(h, layer["attn"], cfg, kv, positions, start)
    x = x + a
    x = _sp(x, cfg)
    h = apply_norm(x, layer["mlp_norm"], cfg.norm_type)
    quant = get_quant(cfg)
    if cfg.moe is not None:
        y = moe_forward(h, layer["moe"], cfg)
        if cfg.moe.dense_residual:
            y = y + mlp_forward(h, layer["dense_mlp"], cfg.mlp_type, quant)
    else:
        y = mlp_forward(h, layer["mlp"], cfg.mlp_type, quant)
    out = _sp(x + y, cfg)
    return out if kv is None else (out, kv)


def _scan_layers(x, stacked, body, remat: bool, unroll: int = 1):
    fn = jax.checkpoint(body) if remat else body

    def step(carry, layer):
        return fn(carry, layer), None

    out, _ = jax.lax.scan(step, x, stacked, unroll=unroll)
    return out


def forward(
    params: dict,
    cfg: ModelConfig,
    *,
    tokens: Optional[jax.Array] = None,  # [B, S] int32
    embeds: Optional[jax.Array] = None,  # [B, S, d] (frontend-stub archs)
    positions: Optional[jax.Array] = None,
) -> jax.Array:
    """Full-sequence forward -> logits [B, S, V]."""
    if embeds is not None:
        x = embeds.astype(cfg.activation_dtype)
    else:
        x = params["embed"][tokens]
    b, s = x.shape[:2]
    if positions is None:
        positions = _default_positions(cfg, b, s)

    if cfg.family in ("dense", "moe", "vlm", "encoder"):
        body = lambda h, layer: _transformer_block(h, layer, cfg, positions)  # noqa: E731
        x = _scan_layers(x, params["layers"], body, cfg.remat, cfg.scan_unroll)
    elif cfg.family == "hybrid":
        shared = params["shared_attn"]
        every = max(cfg.attn_every, 1)

        def hybrid_body(carry, inp):
            h, = carry
            layer, idx = inp

            def with_attn(h):
                return _transformer_block(h, shared, cfg, positions)

            h = jax.lax.cond(idx % every == 0, with_attn, lambda h: h, h)
            hn = apply_norm(h, layer["norm"], cfg.norm_type)
            h = h + mamba_forward(hn, layer["mamba"], cfg)
            return (h,), None

        body_fn = jax.checkpoint(hybrid_body) if cfg.remat else hybrid_body
        (x,), _ = jax.lax.scan(
            body_fn,
            (x,),
            (params["mamba_layers"], jnp.arange(cfg.num_layers)),
            unroll=cfg.scan_unroll,
        )
    elif cfg.family == "ssm":
        def ssm_body(h, block):
            hn = apply_norm(h, block["mlstm_norm"], cfg.norm_type)
            h = h + mlstm_forward(hn, block["mlstm"], cfg)
            hn = apply_norm(h, block["slstm_norm"], cfg.norm_type)
            h = h + slstm_forward(hn, block["slstm"], cfg)
            return h

        x = _scan_layers(x, params["blocks"], ssm_body, cfg.remat, cfg.scan_unroll)
    else:
        raise ValueError(cfg.family)

    x = apply_norm(x, params["final_norm"], cfg.norm_type)
    head = params["embed"].T if cfg.tie_embeddings else params["lm_head"]
    logits = x @ head
    if cfg.logit_softcap:
        logits = jnp.tanh(logits / cfg.logit_softcap) * cfg.logit_softcap
    return logits


# ---------------------------------------------------------------------------
# decode (single new token against caches)
# ---------------------------------------------------------------------------


def init_cache(cfg: ModelConfig, batch: int, max_len: int) -> Any:
    dtype = cfg.activation_dtype
    if cfg.family in ("dense", "moe", "vlm"):
        def one(_):
            return init_kv_cache(cfg, batch, max_len, dtype)

        return jax.vmap(one)(jnp.arange(cfg.num_layers))
    if cfg.family == "hybrid":
        every = max(cfg.attn_every, 1)
        n_attn = (cfg.num_layers + every - 1) // every
        return {
            "attn": jax.vmap(lambda _: init_kv_cache(cfg, batch, max_len, dtype))(
                jnp.arange(n_attn)
            ),
            "mamba": jax.vmap(lambda _: init_mamba_cache(cfg, batch, dtype))(
                jnp.arange(cfg.num_layers)
            ),
        }
    if cfg.family == "ssm":
        n_blocks = cfg.num_layers // 2
        return {
            "mlstm": jax.vmap(lambda _: init_mlstm_state(cfg, batch))(
                jnp.arange(n_blocks)
            ),
            "slstm": jax.vmap(lambda _: init_slstm_state(cfg, batch))(
                jnp.arange(n_blocks)
            ),
        }
    raise ValueError(f"{cfg.family} has no decode step (encoder-only)")


def decode_step(
    params: dict,
    cfg: ModelConfig,
    tokens: jax.Array,  # [B, 1] int32
    cache: Any,
    position: jax.Array,  # scalar or [B] int32: absolute position per slot
) -> tuple[jax.Array, Any]:
    """One decode step -> (logits [B, 1, V], new cache).

    ``position`` may be a scalar (all slots at the same depth — the
    static-batch path) or a per-slot ``[B]`` vector (continuous batching:
    each slot decodes at its own depth)."""
    x = params["embed"][tokens]
    b = x.shape[0]
    position = jnp.asarray(position, jnp.int32)
    pos = jnp.broadcast_to(position.reshape(-1, 1), (b, 1))
    if cfg.mrope_sections is not None:
        pos = jnp.broadcast_to(pos[..., None], (b, 1, 3))

    if cfg.family in ("dense", "moe", "vlm"):
        def body(h, inp):
            layer, kv = inp
            hn = apply_norm(h, layer["attn_norm"], cfg.norm_type)
            a, kv_new = decode_attention(hn, layer["attn"], cfg, kv, pos)
            h = h + a
            hn = apply_norm(h, layer["mlp_norm"], cfg.norm_type)
            quant = get_quant(cfg)
            if cfg.moe is not None:
                # dropless: a decode token's routing must not depend on its
                # lane-mates (dead slots, other slots' depths) — capacity
                # competition across lanes would break per-slot determinism.
                y = moe_forward(hn, layer["moe"], cfg, dropless=True)
                if cfg.moe.dense_residual:
                    y = y + mlp_forward(hn, layer["dense_mlp"], cfg.mlp_type, quant)
            else:
                y = mlp_forward(hn, layer["mlp"], cfg.mlp_type, quant)
            return h + y, kv_new

        x, new_cache = jax.lax.scan(
            body, x, (params["layers"], cache), unroll=cfg.scan_unroll
        )
    elif cfg.family == "hybrid":
        shared = params["shared_attn"]
        every = max(cfg.attn_every, 1)
        n_attn = (cfg.num_layers + every - 1) // every

        def hybrid_body(carry, inp):
            h = carry
            layer, mamba_cache, idx = inp

            def with_attn(args):
                h, kv = args
                hn = apply_norm(h, shared["attn_norm"], cfg.norm_type)
                a, kv_new = decode_attention(hn, shared["attn"], cfg, kv, pos)
                h = h + a
                hn = apply_norm(h, shared["mlp_norm"], cfg.norm_type)
                h = h + mlp_forward(hn, shared["mlp"], cfg.mlp_type, get_quant(cfg))
                return h, kv_new

            attn_slot = idx // every
            kv = jax.tree.map(lambda c: c[attn_slot], cache["attn"])
            h, kv_new = jax.lax.cond(
                idx % every == 0, with_attn, lambda a: (a[0], a[1]), (h, kv)
            )
            hn = apply_norm(h, layer["norm"], cfg.norm_type)
            m, mc_new = mamba_decode(hn, layer["mamba"], cfg, mamba_cache)
            # Non-attention layers must not write their (stale) slot echo:
            # route their scatter index out of bounds (dropped below).
            write_idx = jnp.where(idx % every == 0, attn_slot, n_attn)
            return h + m, (kv_new, write_idx, mc_new)

        x, (kvs, slots, mcs) = jax.lax.scan(
            hybrid_body,
            x,
            (params["mamba_layers"], cache["mamba"], jnp.arange(cfg.num_layers)),
            unroll=cfg.scan_unroll,
        )
        # Scatter updated attention caches back: exactly one layer per slot
        # carries a valid index; all others were routed out of bounds and
        # are dropped by the scatter.
        new_attn = jax.tree.map(
            lambda stacked, upd: stacked.at[slots].set(upd, mode="drop"),
            cache["attn"],
            kvs,
        )
        new_cache = {"attn": new_attn, "mamba": mcs}
    elif cfg.family == "ssm":
        def ssm_body(h, inp):
            block, ms, ss = inp
            hn = apply_norm(h, block["mlstm_norm"], cfg.norm_type)
            y, ms_new = mlstm_decode(hn, block["mlstm"], cfg, ms)
            h = h + y
            hn = apply_norm(h, block["slstm_norm"], cfg.norm_type)
            y, ss_new = slstm_decode(hn, block["slstm"], cfg, ss)
            return h + y, (ms_new, ss_new)

        x, (ms_all, ss_all) = jax.lax.scan(
            ssm_body,
            x,
            (params["blocks"], cache["mlstm"], cache["slstm"]),
            unroll=cfg.scan_unroll,
        )
        new_cache = {"mlstm": ms_all, "slstm": ss_all}
    else:
        raise ValueError(cfg.family)

    x = apply_norm(x, params["final_norm"], cfg.norm_type)
    head = params["embed"].T if cfg.tie_embeddings else params["lm_head"]
    logits = x @ head
    return logits, new_cache


# ---------------------------------------------------------------------------
# speculative verify (K+1 teacher-forced tokens against the live cache)
# ---------------------------------------------------------------------------


def verify_step(
    params: dict,
    cfg: ModelConfig,
    tokens: jax.Array,  # [B, S] int32: [last sampled token, K draft tokens]
    cache: Any,  # the *live* decode cache (batch B, capacity max_len)
    positions: jax.Array,  # [B] int32: per-slot first write position
) -> tuple[jax.Array, Any]:
    """Score S teacher-forced tokens per slot in one batched forward.

    The speculative-decoding verify pass (repro.spec): slot i's tokens
    occupy absolute positions ``positions[i] + [0, S)``; their K/V are
    written straight into the live decode cache at those per-slot rows and
    every token attends exactly the prefix a sequential ``decode_step``
    would have seen, so ``argmax(logits[:, j])`` equals the vanilla greedy
    token given the prefix plus ``tokens[:, :j+1]``.

    ``cache.lengths`` is *not* advanced here — the caller decides how many
    proposed tokens survive and truncates via ``rollback_cache``.  Only
    attention families support this (recurrent Mamba/xLSTM state cannot be
    rolled back by length truncation).
    """
    if cfg.family not in ("dense", "moe", "vlm"):
        raise ValueError(
            f"verify_step requires an attention-family cache (KV rollback); "
            f"{cfg.family!r} carries recurrent state"
        )
    x = params["embed"][tokens]
    b, s = tokens.shape
    positions = jnp.asarray(positions, jnp.int32)
    pos = positions[:, None] + jnp.arange(s, dtype=jnp.int32)[None, :]  # [B, S]
    write_pos = positions
    if cfg.mrope_sections is not None:
        pos = jnp.broadcast_to(pos[..., None], (b, s, 3))

    def body(h, inp):
        layer, kv = inp
        hn = apply_norm(h, layer["attn_norm"], cfg.norm_type)
        a, kv_new = verify_attention(hn, layer["attn"], cfg, kv, pos, write_pos)
        h = h + a
        hn = apply_norm(h, layer["mlp_norm"], cfg.norm_type)
        quant = get_quant(cfg)
        if cfg.moe is not None:
            # Same dropless routing as decode_step: verify row j must equal
            # the decode step it replaces regardless of lane-mates.
            y = moe_forward(hn, layer["moe"], cfg, dropless=True)
            if cfg.moe.dense_residual:
                y = y + mlp_forward(hn, layer["dense_mlp"], cfg.mlp_type, quant)
        else:
            y = mlp_forward(hn, layer["mlp"], cfg.mlp_type, quant)
        return h + y, kv_new

    x, new_cache = jax.lax.scan(
        body, x, (params["layers"], cache), unroll=cfg.scan_unroll
    )
    x = apply_norm(x, params["final_norm"], cfg.norm_type)
    head = params["embed"].T if cfg.tie_embeddings else params["lm_head"]
    # No logit softcap, matching decode_step: tanh is monotonic, so the
    # greedy argmax the engine compares/emits is unchanged either way.
    logits = x @ head
    return logits, new_cache


def rollback_cache(cache: Any, new_lengths: jax.Array) -> Any:
    """Truncate every slot's cached length to ``new_lengths`` [B].

    Speculative-decoding rejection rollback: rejected suffix rows stay in
    the buffers but become invisible — every attention read masks keys
    beyond ``lengths`` and every subsequent write scatters at ``lengths``,
    so stale rows are never read and are overwritten in place.  Works for
    both fp32 ``KVCache`` and int8 ``QuantKVCache`` (stacked ``[L, B, ...]``
    leaves with ``lengths [L, B]``); recurrent-state caches cannot roll
    back this way and are rejected.
    """
    if not isinstance(cache, (KVCache, QuantKVCache)):
        raise ValueError(
            "rollback_cache requires a KVCache/QuantKVCache (attention "
            "families); recurrent state has no length-truncation rollback"
        )
    new_lengths = jnp.asarray(new_lengths, jnp.int32)
    return cache._replace(
        lengths=jnp.broadcast_to(new_lengths[None, :], cache.lengths.shape)
    )


# ---------------------------------------------------------------------------
# prefill (whole prompt into the cache) + slot insert
# ---------------------------------------------------------------------------


def _prefill_chunk(params: dict, cfg: ModelConfig, tokens_c, cache, start: int):
    """One prefill chunk through the transformer stack: each layer writes
    its K/V into the cache and flash-attends over [0, start+C)."""
    x = params["embed"][tokens_c]
    b, c = tokens_c.shape
    positions = _default_positions(cfg, b, c, offset=start)

    def body(h, inp):
        layer, kv = inp
        return _transformer_block(h, layer, cfg, positions, kv=kv, start=start)

    x, new_cache = jax.lax.scan(
        body, x, (params["layers"], cache), unroll=cfg.scan_unroll
    )
    x = apply_norm(x, params["final_norm"], cfg.norm_type)
    head = params["embed"].T if cfg.tie_embeddings else params["lm_head"]
    logits = x @ head
    if cfg.logit_softcap:
        logits = jnp.tanh(logits / cfg.logit_softcap) * cfg.logit_softcap
    return logits, new_cache


def _prefill_by_scan(params: dict, cfg: ModelConfig, tokens, cache, lengths):
    """Family-agnostic prefill fallback: teacher-force the prompt through
    ``decode_step`` under one ``lax.scan`` (a single jit invocation, not an
    O(prompt_len) Python loop).  Per-slot state updates are frozen once the
    scan passes a slot's true length, so right-padded prompts don't pollute
    recurrent (Mamba/xLSTM) states with pad tokens."""
    b, s = tokens.shape

    def body(c, inp):
        tok, pos = inp
        logits, new_c = decode_step(params, cfg, tok[:, None], c, pos)
        keep = pos < lengths  # [B]

        def sel(n, o):
            return jnp.where(keep.reshape((1, b) + (1,) * (n.ndim - 2)), n, o)

        return jax.tree.map(sel, new_c, c), logits[:, 0]

    cache, logits = jax.lax.scan(
        body, cache, (tokens.T, jnp.arange(s, dtype=jnp.int32))
    )
    return jnp.moveaxis(logits, 0, 1), cache


def prefill_step(
    params: dict,
    cfg: ModelConfig,
    tokens: jax.Array,  # [B, S] int32, right-padded to the bucket length
    cache: Any,  # from ``init_cache(cfg, B, S')`` with S' >= S
    lengths: jax.Array,  # [B] int32: true prompt length per row
    *,
    chunk_size: Optional[int] = None,
) -> tuple[jax.Array, Any]:
    """Prefill a (padded) prompt batch into ``cache`` -> (logits [B,S,V], cache).

    Attention families run the chunked flash path — ``flash_attention`` is
    called once per chunk of ``chunk_size`` tokens (default: the whole
    prompt in one call) and K/V are written straight into the cache, no
    per-token loop and no second pass.  Recurrent families (hybrid/ssm)
    teacher-force through ``decode_step`` under a single ``lax.scan``.
    """
    b, s = tokens.shape
    lengths = jnp.asarray(lengths, jnp.int32).reshape(b)
    if cfg.family in ("dense", "moe", "vlm"):
        chunk = min(int(chunk_size), s) if chunk_size else s
        logits = []
        for start in range(0, s, chunk):
            lg, cache = _prefill_chunk(
                params, cfg, tokens[:, start : start + chunk], cache, start
            )
            logits.append(lg)
        out = logits[0] if len(logits) == 1 else jnp.concatenate(logits, axis=1)
        cache = cache._replace(
            lengths=jnp.broadcast_to(lengths[None, :], cache.lengths.shape)
        )
        return out, cache
    if cfg.family == "encoder":
        raise ValueError("encoder archs have no decode cache to prefill")
    return _prefill_by_scan(params, cfg, tokens, cache, lengths)


def insert_cache(cache: Any, prefix: Any, slot: jax.Array) -> Any:
    """Copy a prefilled cache (batch dim 1, seq capacity <= max_len) into
    batch slot ``slot`` of a decode cache.  Family-agnostic: every stacked
    cache leaf is [L, B, ...] with batch at dim 1 (KV tensors, per-slot
    lengths, Mamba/xLSTM states alike), so one dynamic_update_slice per
    leaf moves the whole request."""

    def one(dst, src):
        start = (0, slot) + (0,) * (dst.ndim - 2)
        return jax.lax.dynamic_update_slice(dst, src.astype(dst.dtype), start)

    return jax.tree.map(one, cache, prefix)


# ---------------------------------------------------------------------------
# loss
# ---------------------------------------------------------------------------


def lm_loss(
    params: dict,
    cfg: ModelConfig,
    batch: dict,
) -> jax.Array:
    """Next-token (or frame-label) cross entropy; labels < 0 are masked."""
    logits = forward(
        params,
        cfg,
        tokens=batch.get("tokens"),
        embeds=batch.get("embeds"),
        positions=batch.get("positions"),
    )
    labels = batch["labels"]
    logits = logits.astype(jnp.float32)
    logp = jax.nn.log_softmax(logits, axis=-1)
    mask = (labels >= 0).astype(jnp.float32)
    safe = jnp.maximum(labels, 0)
    nll = -jnp.take_along_axis(logp, safe[..., None], axis=-1)[..., 0]
    return jnp.sum(nll * mask) / jnp.maximum(jnp.sum(mask), 1.0)
