"""GQA attention layer: params, forward (train/prefill), decode with KV cache.

The paper's technique enters here: ``cfg.attention_impl`` selects

  * ``systolic`` — the Algorithm-1-faithful tiled jnp implementation
    (``repro.core.attention``), lowers on all backends; the dry-run path;
  * ``pallas``   — the fused Pallas TPU kernel (``repro.kernels``);
  * ``naive``    — materialized softmax (oracle / tiny decode steps).

Per the paper §8.3, decode (seq_q == 1, memory-bound) never uses the FSA
path: a 1-token query would waste a 128x128 tile.  ``decode_attention``
is a plain einsum over the KV cache.
"""

from __future__ import annotations

from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.core.attention import naive_attention, systolic_attention
from repro.kernels.flash_attention.ops import flash_attention
from .layers import apply_mrope, apply_rope, dense_init, rms_norm


class KVCache(NamedTuple):
    k: jax.Array  # [B, max_len, Hkv, d]
    v: jax.Array  # [B, max_len, Hkv, d]
    length: jax.Array  # scalar int32: tokens already cached


def attention_params(key, cfg: ModelConfig, dtype) -> dict:
    d = cfg.d_model
    hd = cfg.resolved_head_dim
    keys = jax.random.split(key, 6)
    p = {
        "wq": dense_init(keys[0], d, cfg.num_heads * hd, dtype),
        "wk": dense_init(keys[1], d, cfg.num_kv_heads * hd, dtype),
        "wv": dense_init(keys[2], d, cfg.num_kv_heads * hd, dtype),
        "wo": dense_init(keys[3], cfg.num_heads * hd, d, dtype),
    }
    if cfg.qkv_bias:
        p["bq"] = jnp.zeros((cfg.num_heads * hd,), dtype)
        p["bk"] = jnp.zeros((cfg.num_kv_heads * hd,), dtype)
        p["bv"] = jnp.zeros((cfg.num_kv_heads * hd,), dtype)
    if cfg.qk_norm:  # qwen3-style per-head q/k RMSNorm
        p["q_norm"] = jnp.ones((hd,), dtype)
        p["k_norm"] = jnp.ones((hd,), dtype)
    return p


def _project_qkv(x, params, cfg: ModelConfig, positions):
    b, s, _ = x.shape
    hd = cfg.resolved_head_dim
    q = x @ params["wq"]
    k = x @ params["wk"]
    v = x @ params["wv"]
    if cfg.qkv_bias:
        q, k, v = q + params["bq"], k + params["bk"], v + params["bv"]
    q = q.reshape(b, s, cfg.num_heads, hd)
    k = k.reshape(b, s, cfg.num_kv_heads, hd)
    v = v.reshape(b, s, cfg.num_kv_heads, hd)
    if cfg.qk_norm:
        q = rms_norm(q, params["q_norm"])
        k = rms_norm(k, params["k_norm"])
    if cfg.mrope_sections is not None:
        q = apply_mrope(q, positions, cfg.mrope_sections, cfg.rope_theta)
        k = apply_mrope(k, positions, cfg.mrope_sections, cfg.rope_theta)
    else:
        q = apply_rope(q, positions, cfg.rope_theta)
        k = apply_rope(k, positions, cfg.rope_theta)
    return q, k, v


def attention_forward(
    x: jax.Array,  # [B, S, d_model]
    params: dict,
    cfg: ModelConfig,
    positions: jax.Array,  # [B, S] (or [B, S, 3] for M-RoPE)
) -> jax.Array:
    """Full-sequence attention (training / prefill)."""
    b, s, _ = x.shape
    q, k, v = _project_qkv(x, params, cfg, positions)
    if cfg.attention_impl == "naive":
        o = naive_attention(q, k, v, causal=cfg.causal)
    elif cfg.attention_impl == "pallas":
        o = flash_attention(
            q, k, v, cfg.causal, None, 0,
            cfg.attn_block_q, cfg.attn_block_k, cfg.exp2_impl, 8, "pallas",
        )
    else:  # systolic (paper-faithful jnp; dry-run / CPU path)
        o = systolic_attention(
            q, k, v,
            causal=cfg.causal,
            block_q=cfg.attn_block_q,
            block_k=cfg.attn_block_k,
            exp2_impl=cfg.exp2_impl,
            unroll=cfg.attn_unroll,
        )
    o = o.reshape(b, s, cfg.num_heads * cfg.resolved_head_dim)
    return o @ params["wo"]


def init_kv_cache(cfg: ModelConfig, batch: int, max_len: int, dtype) -> KVCache:
    hd = cfg.resolved_head_dim
    return KVCache(
        k=jnp.zeros((batch, max_len, cfg.num_kv_heads, hd), dtype),
        v=jnp.zeros((batch, max_len, cfg.num_kv_heads, hd), dtype),
        length=jnp.zeros((), jnp.int32),
    )


def decode_attention(
    x: jax.Array,  # [B, 1, d_model]
    params: dict,
    cfg: ModelConfig,
    cache: KVCache,
    positions: jax.Array,  # [B, 1] (or [B, 1, 3])
) -> tuple[jax.Array, KVCache]:
    """Single-token decode against the KV cache (paper §8.3: never FSA)."""
    b = x.shape[0]
    hd = cfg.resolved_head_dim
    q, k_new, v_new = _project_qkv(x, params, cfg, positions)

    k = jax.lax.dynamic_update_slice_in_dim(cache.k, k_new.astype(cache.k.dtype), cache.length, axis=1)
    v = jax.lax.dynamic_update_slice_in_dim(cache.v, v_new.astype(cache.v.dtype), cache.length, axis=1)
    new_cache = KVCache(k=k, v=v, length=cache.length + 1)

    # GQA via grouped einsum — materializing jnp.repeat(k, rep) would blow
    # the cache up rep x (16x for qwen3) and force GSPMD to reshard it every
    # step (measured: the dominant decode collective cost).
    rep = cfg.num_heads // cfg.num_kv_heads
    qg = q.reshape(b, 1, cfg.num_kv_heads, rep, hd).astype(jnp.float32)
    scale = 1.0 / jnp.sqrt(jnp.asarray(hd, jnp.float32))
    s = jnp.einsum("bqhrd,bkhd->bhrqk", qg, k.astype(jnp.float32)) * scale
    # Mask positions beyond the (updated) cache length.
    valid = jnp.arange(k.shape[1])[None, None, None, None, :] <= cache.length
    s = jnp.where(valid, s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bhrqk,bkhd->bqhrd", p, v.astype(jnp.float32)).astype(x.dtype)
    o = o.reshape(b, 1, cfg.num_heads * hd)
    return o @ params["wo"], new_cache
