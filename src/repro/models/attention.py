"""GQA attention layer: params, forward (train/prefill), decode with KV cache.

The paper's technique enters here: ``cfg.attention_impl`` selects

  * ``systolic`` — the Algorithm-1-faithful tiled jnp implementation
    (``repro.core.attention``), lowers on all backends; the dry-run path;
  * ``pallas``   — the fused Pallas TPU kernel (``repro.kernels``);
  * ``naive``    — materialized softmax (oracle / tiny decode steps).

Per the paper §8.3, decode (seq_q == 1, memory-bound) never uses the FSA
path: a 1-token query would waste a 128x128 tile.  ``decode_attention``
is a plain einsum over the KV cache.
"""

from __future__ import annotations

from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.core.attention import naive_attention, systolic_attention
from repro.kernels.flash_attention.ops import flash_attention
from repro.quant import dequantize_kv, get_quant, quantize_kv
from .layers import apply_mrope, apply_rope, dense_init, rms_norm


class KVCache(NamedTuple):
    k: jax.Array  # [B, max_len, Hkv, d]
    v: jax.Array  # [B, max_len, Hkv, d]
    lengths: jax.Array  # [B] int32: tokens cached per batch slot


class QuantKVCache(NamedTuple):
    """int8 KV storage (repro.quant): payloads + per-token/head scales.

    Field order keeps ``lengths`` last and batch at dim 0 of every array
    leaf, preserving the ``insert_cache`` / ``cache_shardings`` invariants
    of the float cache.  Scales are fp32 [B, max_len, Hkv] — 4 bytes per
    cached vector next to ``head_dim`` int8 payload bytes.
    """

    k: jax.Array  # int8 [B, max_len, Hkv, d]
    v: jax.Array  # int8 [B, max_len, Hkv, d]
    k_scale: jax.Array  # f32 [B, max_len, Hkv]
    v_scale: jax.Array  # f32 [B, max_len, Hkv]
    lengths: jax.Array  # [B] int32: tokens cached per batch slot


def attention_params(key, cfg: ModelConfig, dtype) -> dict:
    d = cfg.d_model
    hd = cfg.resolved_head_dim
    keys = jax.random.split(key, 6)
    p = {
        "wq": dense_init(keys[0], d, cfg.num_heads * hd, dtype),
        "wk": dense_init(keys[1], d, cfg.num_kv_heads * hd, dtype),
        "wv": dense_init(keys[2], d, cfg.num_kv_heads * hd, dtype),
        "wo": dense_init(keys[3], cfg.num_heads * hd, d, dtype),
    }
    if cfg.qkv_bias:
        p["bq"] = jnp.zeros((cfg.num_heads * hd,), dtype)
        p["bk"] = jnp.zeros((cfg.num_kv_heads * hd,), dtype)
        p["bv"] = jnp.zeros((cfg.num_kv_heads * hd,), dtype)
    if cfg.qk_norm:  # qwen3-style per-head q/k RMSNorm
        p["q_norm"] = jnp.ones((hd,), dtype)
        p["k_norm"] = jnp.ones((hd,), dtype)
    return p


def _project_qkv(x, params, cfg: ModelConfig, positions):
    b, s, _ = x.shape
    hd = cfg.resolved_head_dim
    quant = get_quant(cfg)
    q = quant.dot(x, params["wq"], "attention")
    k = quant.dot(x, params["wk"], "attention")
    v = quant.dot(x, params["wv"], "attention")
    if cfg.qkv_bias:
        q, k, v = q + params["bq"], k + params["bk"], v + params["bv"]
    q = q.reshape(b, s, cfg.num_heads, hd)
    k = k.reshape(b, s, cfg.num_kv_heads, hd)
    v = v.reshape(b, s, cfg.num_kv_heads, hd)
    if cfg.qk_norm:
        q = rms_norm(q, params["q_norm"])
        k = rms_norm(k, params["k_norm"])
    if cfg.mrope_sections is not None:
        q = apply_mrope(q, positions, cfg.mrope_sections, cfg.rope_theta)
        k = apply_mrope(k, positions, cfg.mrope_sections, cfg.rope_theta)
    else:
        q = apply_rope(q, positions, cfg.rope_theta)
        k = apply_rope(k, positions, cfg.rope_theta)
    return q, k, v


def _impl_attention(q, k, v, cfg: ModelConfig, q_offset: int = 0) -> jax.Array:
    """Dispatch full-sequence attention to the configured implementation.

    Shared by training/prefill (``attention_forward``) and the chunked
    flash prefill (``prefill_attention``) so both paths produce identical
    numerics for the same (q, k, v) — the token-equivalence contract of
    the serving engine depends on this.
    """
    if cfg.attention_impl == "naive":
        return naive_attention(q, k, v, causal=cfg.causal, q_offset=q_offset)
    if cfg.attention_impl == "pallas":
        return flash_attention(
            q, k, v, cfg.causal, None, q_offset,
            cfg.attn_block_q, cfg.attn_block_k, cfg.exp2_impl, 8, "pallas",
        )
    # systolic (paper-faithful jnp; dry-run / CPU path)
    return systolic_attention(
        q, k, v,
        causal=cfg.causal,
        q_offset=q_offset,
        block_q=cfg.attn_block_q,
        block_k=cfg.attn_block_k,
        exp2_impl=cfg.exp2_impl,
        unroll=cfg.attn_unroll,
    )


def attention_forward(
    x: jax.Array,  # [B, S, d_model]
    params: dict,
    cfg: ModelConfig,
    positions: jax.Array,  # [B, S] (or [B, S, 3] for M-RoPE)
) -> jax.Array:
    """Full-sequence attention (training / prefill)."""
    b, s, _ = x.shape
    q, k, v = _project_qkv(x, params, cfg, positions)
    o = _impl_attention(q, k, v, cfg)
    o = o.reshape(b, s, cfg.num_heads * cfg.resolved_head_dim)
    return get_quant(cfg).dot(o, params["wo"], "attention")


def prefill_attention(
    x: jax.Array,  # [B, C, d_model] — one prefill chunk
    params: dict,
    cfg: ModelConfig,
    cache: KVCache,  # seq capacity >= start + C
    positions: jax.Array,  # [B, C] (or [B, C, 3]) absolute positions
    start: int,  # static chunk offset: tokens [0, start) are already cached
) -> tuple[jax.Array, KVCache]:
    """Chunked flash prefill: write the chunk's K/V straight into the cache
    and attend the chunk's queries over everything cached so far.

    One flash-attention call per chunk (no per-token loop): causality
    against the earlier chunks comes from ``q_offset=start``.  ``start`` is
    a Python int (the chunk schedule is unrolled inside jit), so the K/V
    span ``[:start+C]`` is a static slice.  ``cache.lengths`` is left for
    the caller to set once the full prompt is in.
    """
    b, c, _ = x.shape
    q, k_new, v_new = _project_qkv(x, params, cfg, positions)
    if get_quant(cfg).quantized_kv:
        # Quantize on insert: each token/head vector gets its own scale, so
        # the chunk write is byte-identical to what a per-token decode
        # scatter-write would have produced.
        kq, ks = quantize_kv(k_new)
        vq, vs = quantize_kv(v_new)
        dus = jax.lax.dynamic_update_slice_in_dim
        new_cache = QuantKVCache(
            k=dus(cache.k, kq, start, axis=1),
            v=dus(cache.v, vq, start, axis=1),
            k_scale=dus(cache.k_scale, ks, start, axis=1),
            v_scale=dus(cache.v_scale, vs, start, axis=1),
            lengths=cache.lengths,
        )
        span = slice(None, start + c)
        k = dequantize_kv(new_cache.k[:, span], new_cache.k_scale[:, span], x.dtype)
        v = dequantize_kv(new_cache.v[:, span], new_cache.v_scale[:, span], x.dtype)
        o = _impl_attention(q, k, v, cfg, q_offset=start)
    else:
        k = jax.lax.dynamic_update_slice_in_dim(
            cache.k, k_new.astype(cache.k.dtype), start, axis=1
        )
        v = jax.lax.dynamic_update_slice_in_dim(
            cache.v, v_new.astype(cache.v.dtype), start, axis=1
        )
        new_cache = KVCache(k=k, v=v, lengths=cache.lengths)
        o = _impl_attention(
            q, k[:, : start + c], v[:, : start + c], cfg, q_offset=start
        )
    o = o.reshape(b, c, cfg.num_heads * cfg.resolved_head_dim)
    return get_quant(cfg).dot(o, params["wo"], "attention"), new_cache


def init_kv_cache(cfg: ModelConfig, batch: int, max_len: int, dtype):
    hd = cfg.resolved_head_dim
    hkv = cfg.num_kv_heads
    if get_quant(cfg).quantized_kv:
        return QuantKVCache(
            k=jnp.zeros((batch, max_len, hkv, hd), jnp.int8),
            v=jnp.zeros((batch, max_len, hkv, hd), jnp.int8),
            k_scale=jnp.zeros((batch, max_len, hkv), jnp.float32),
            v_scale=jnp.zeros((batch, max_len, hkv), jnp.float32),
            lengths=jnp.zeros((batch,), jnp.int32),
        )
    return KVCache(
        k=jnp.zeros((batch, max_len, hkv, hd), dtype),
        v=jnp.zeros((batch, max_len, hkv, hd), dtype),
        lengths=jnp.zeros((batch,), jnp.int32),
    )


def verify_attention(
    x: jax.Array,  # [B, S, d_model] — S teacher-forced tokens per slot
    params: dict,
    cfg: ModelConfig,
    cache: KVCache,
    positions: jax.Array,  # [B, S] (or [B, S, 3]) absolute positions
    write_pos: jax.Array,  # [B] int32: first write row per slot
) -> tuple[jax.Array, KVCache]:
    """Batched speculative-verify attention: score S tokens per slot in one
    pass against the *live* decode cache.

    The spec-decoding core (repro.spec): S = K+1 proposed tokens enter as
    one wide teacher-forced chunk — the consecutive-large-matmul shape the
    paper's FSA scheduling thrives on, instead of K memory-bound 1-token
    decode steps.  Slot i's rows are scattered at ``write_pos[i] + j`` (its
    own decode depth, unlike ``prefill_attention``'s batch-static ``start``)
    and query j attends keys at absolute positions ``<= write_pos[i] + j``.
    Row j therefore sees exactly the cache a sequential ``decode_attention``
    step would have seen, so greedy acceptance is lossless.

    ``cache.lengths`` is left untouched: acceptance (and the rollback that
    truncates rejected suffixes) is decided by the caller once the verify
    logits are known — see ``repro.spec.verify``.
    """
    b, s_new, _ = x.shape
    hd = cfg.resolved_head_dim
    q, k_new, v_new = _project_qkv(x, params, cfg, positions)

    slot = jnp.arange(b)[:, None]  # [B, 1]
    rows = write_pos[:, None] + jnp.arange(s_new)[None, :]  # [B, S]
    if get_quant(cfg).quantized_kv:
        # Same per-token/head quantize-on-write as the decode scatter, so
        # accepted rows are byte-identical to sequential decode's writes.
        kq, ks = quantize_kv(k_new)
        vq, vs = quantize_kv(v_new)
        new_cache = QuantKVCache(
            k=cache.k.at[slot, rows].set(kq, mode="drop"),
            v=cache.v.at[slot, rows].set(vq, mode="drop"),
            k_scale=cache.k_scale.at[slot, rows].set(ks, mode="drop"),
            v_scale=cache.v_scale.at[slot, rows].set(vs, mode="drop"),
            lengths=cache.lengths,
        )
        k = dequantize_kv(new_cache.k, new_cache.k_scale)
        v = dequantize_kv(new_cache.v, new_cache.v_scale)
    else:
        k = cache.k.at[slot, rows].set(k_new.astype(cache.k.dtype), mode="drop")
        v = cache.v.at[slot, rows].set(v_new.astype(cache.v.dtype), mode="drop")
        new_cache = KVCache(k=k, v=v, lengths=cache.lengths)

    # Same grouped-einsum formulation (and fp32 softmax) as
    # ``decode_attention``, widened from 1 query to S — the mask reduces to
    # decode's ``key <= lengths`` row by row, which is what keeps verify
    # argmax-identical to the sequential decode it replaces.
    rep = cfg.num_heads // cfg.num_kv_heads
    qg = q.reshape(b, s_new, cfg.num_kv_heads, rep, hd).astype(jnp.float32)
    scale = 1.0 / jnp.sqrt(jnp.asarray(hd, jnp.float32))
    s = jnp.einsum("bqhrd,bkhd->bhrqk", qg, k.astype(jnp.float32)) * scale
    valid = (
        jnp.arange(k.shape[1])[None, None, None, None, :]
        <= rows[:, None, None, :, None]
    )
    s = jnp.where(valid, s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bhrqk,bkhd->bqhrd", p, v.astype(jnp.float32)).astype(x.dtype)
    o = o.reshape(b, s_new, cfg.num_heads * hd)
    return get_quant(cfg).dot(o, params["wo"], "attention"), new_cache


def decode_attention(
    x: jax.Array,  # [B, 1, d_model]
    params: dict,
    cfg: ModelConfig,
    cache: KVCache,
    positions: jax.Array,  # [B, 1] (or [B, 1, 3])
) -> tuple[jax.Array, KVCache]:
    """Single-token decode against the KV cache (paper §8.3: never FSA).

    Per-slot positions: slot i's new K/V is scattered at ``lengths[i]``, so
    requests at arbitrary decode depths share one batched step (continuous
    batching).  Slots whose length has reached capacity drop their write.
    """
    b = x.shape[0]
    hd = cfg.resolved_head_dim
    q, k_new, v_new = _project_qkv(x, params, cfg, positions)

    slot = jnp.arange(b)
    if get_quant(cfg).quantized_kv:
        # Quantize on the decode scatter-write; attention below runs over
        # the dequantized cache (identical values to the prefill path).
        kq, ks = quantize_kv(k_new[:, 0])
        vq, vs = quantize_kv(v_new[:, 0])
        new_cache = QuantKVCache(
            k=cache.k.at[slot, cache.lengths].set(kq, mode="drop"),
            v=cache.v.at[slot, cache.lengths].set(vq, mode="drop"),
            k_scale=cache.k_scale.at[slot, cache.lengths].set(ks, mode="drop"),
            v_scale=cache.v_scale.at[slot, cache.lengths].set(vs, mode="drop"),
            lengths=cache.lengths + 1,
        )
        k = dequantize_kv(new_cache.k, new_cache.k_scale)
        v = dequantize_kv(new_cache.v, new_cache.v_scale)
    else:
        k = cache.k.at[slot, cache.lengths].set(
            k_new[:, 0].astype(cache.k.dtype), mode="drop"
        )
        v = cache.v.at[slot, cache.lengths].set(
            v_new[:, 0].astype(cache.v.dtype), mode="drop"
        )
        new_cache = KVCache(k=k, v=v, lengths=cache.lengths + 1)

    # GQA via grouped einsum — materializing jnp.repeat(k, rep) would blow
    # the cache up rep x (16x for qwen3) and force GSPMD to reshard it every
    # step (measured: the dominant decode collective cost).
    rep = cfg.num_heads // cfg.num_kv_heads
    qg = q.reshape(b, 1, cfg.num_kv_heads, rep, hd).astype(jnp.float32)
    scale = 1.0 / jnp.sqrt(jnp.asarray(hd, jnp.float32))
    s = jnp.einsum("bqhrd,bkhd->bhrqk", qg, k.astype(jnp.float32)) * scale
    # Mask positions beyond each slot's (updated) cache length.
    valid = (
        jnp.arange(k.shape[1])[None, None, None, None, :]
        <= cache.lengths[:, None, None, None, None]
    )
    s = jnp.where(valid, s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bhrqk,bkhd->bqhrd", p, v.astype(jnp.float32)).astype(x.dtype)
    o = o.reshape(b, 1, cfg.num_heads * hd)
    return get_quant(cfg).dot(o, params["wo"], "attention"), new_cache
