"""Mamba2 (SSD) block — chunked state-space dual algorithm in pure JAX.

Used by the zamba2 hybrid architecture.  Implements:

  * input projection -> (z, x, B, C, dt), causal depthwise conv on (x, B, C),
  * scalar-identity state transition per head: h_t = a_t h_{t-1} + dt_t x_t B_t^T,
    y_t = C_t h_t + D x_t, with a_t = exp(-softplus(A_log) * dt_t),
  * chunked evaluation (intra-chunk quadratic attention-like term + inter-chunk
    recurrent state carry), O(S * chunk) instead of O(S^2),
  * gated output (silu(z)) + RMSNorm, out projection,
  * single-token recurrent decode with (conv_state, ssm_state) cache.
"""

from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.quant import get_quant
from .layers import dense_init, rms_norm


class MambaCache(NamedTuple):
    conv: jax.Array  # [B, conv_width - 1, conv_channels]
    ssm: jax.Array  # [B, H, head_dim, state_dim]


def _dims(cfg: ModelConfig):
    ssm = cfg.ssm
    d_inner = ssm.expand * cfg.d_model
    nheads = d_inner // ssm.head_dim
    conv_ch = d_inner + 2 * ssm.state_dim
    return d_inner, nheads, conv_ch


def mamba_params(key, cfg: ModelConfig, dtype) -> dict:
    ssm = cfg.ssm
    d = cfg.d_model
    d_inner, nheads, conv_ch = _dims(cfg)
    keys = jax.random.split(key, 4)
    in_dim = 2 * d_inner + 2 * ssm.state_dim + nheads  # z, x, B, C, dt
    return {
        "in_proj": dense_init(keys[0], d, in_dim, dtype),
        "conv_w": (jax.random.normal(keys[1], (ssm.conv_width, conv_ch), jnp.float32) * 0.1).astype(dtype),
        "conv_b": jnp.zeros((conv_ch,), dtype),
        "A_log": jnp.zeros((nheads,), jnp.float32),
        "D": jnp.ones((nheads,), jnp.float32),
        "dt_bias": jnp.zeros((nheads,), jnp.float32),
        "norm_scale": jnp.ones((d_inner,), dtype),
        "out_proj": dense_init(keys[2], d_inner, d, dtype),
    }


def _split_proj(proj: jax.Array, cfg: ModelConfig):
    ssm = cfg.ssm
    d_inner, nheads, _ = _dims(cfg)
    z, xbc, dt = jnp.split(proj, [d_inner, 2 * d_inner + 2 * ssm.state_dim], axis=-1)
    return z, xbc, dt  # xbc = concat(x, B, C)


def _causal_conv(xbc: jax.Array, w: jax.Array, b: jax.Array) -> jax.Array:
    """Depthwise causal conv over time. xbc: [B, S, C], w: [K, C]."""
    kw = w.shape[0]
    pad = jnp.pad(xbc, ((0, 0), (kw - 1, 0), (0, 0)))
    out = sum(
        pad[:, i : i + xbc.shape[1], :] * w[i][None, None, :] for i in range(kw)
    )
    return jax.nn.silu(out + b)


def _ssd_chunked(
    x: jax.Array,   # [B, S, H, P]   (P = head_dim)
    dt: jax.Array,  # [B, S, H]      (post-softplus)
    a: jax.Array,   # [B, S, H]      log-decay per step: -softplus(A_log)*dt
    B: jax.Array,   # [B, S, N]
    C: jax.Array,   # [B, S, N]
    chunk: int,
    h0: jax.Array | None = None,  # [B, H, P, N]
) -> tuple[jax.Array, jax.Array]:
    """Chunked SSD scan.  Returns (y [B,S,H,P], h_final [B,H,P,N])."""
    b, s, h, p = x.shape
    n = B.shape[-1]
    assert s % chunk == 0, (s, chunk)
    nc = s // chunk

    xc = x.reshape(b, nc, chunk, h, p)
    dtc = dt.reshape(b, nc, chunk, h)
    ac = a.reshape(b, nc, chunk, h)
    Bc = B.reshape(b, nc, chunk, n)
    Cc = C.reshape(b, nc, chunk, n)

    # Cumulative log-decay within each chunk.
    cum = jnp.cumsum(ac, axis=2)  # [B, NC, L, H]
    total = cum[:, :, -1]  # [B, NC, H]

    # Intra-chunk (quadratic within the chunk):
    # y_intra[t] = sum_{u<=t} exp(cum[t]-cum[u]) * (C_t . B_u) * dt_u * x_u
    decay = jnp.exp(cum[:, :, :, None, :] - cum[:, :, None, :, :])  # [B,NC,L,L,H]
    mask = jnp.tril(jnp.ones((chunk, chunk), bool))
    decay = jnp.where(mask[None, None, :, :, None], decay, 0.0)
    scores = jnp.einsum("bctn,bcun->bctu", Cc, Bc)  # [B,NC,L,L]
    w = scores[..., None] * decay * dtc[:, :, None, :, :]  # [B,NC,L,L,H]
    y_intra = jnp.einsum("bctuh,bcuhp->bcthp", w, xc)

    # Chunk-boundary states: h_chunk = sum_u exp(total - cum[u]) dt_u x_u B_u^T
    state_decay = jnp.exp(total[:, :, None, :] - cum)  # [B,NC,L,H]
    xb = jnp.einsum("bcuh,bcuhp,bcun->bchpn", dtc * state_decay, xc, Bc)

    # Inter-chunk recurrence over chunk index (sequential scan of length NC).
    def step(h_prev, inp):
        xb_c, tot_c = inp  # [B,H,P,N], [B,H]
        h_new = h_prev * jnp.exp(tot_c)[..., None, None] + xb_c
        return h_new, h_prev

    if h0 is None:
        h0 = jnp.zeros((b, h, p, n), x.dtype)
    xb_t = jnp.moveaxis(xb, 1, 0)  # [NC, B, H, P, N]
    tot_t = jnp.moveaxis(total, 1, 0)  # [NC, B, H]
    h_final, h_starts = jax.lax.scan(step, h0, (xb_t, tot_t))
    h_starts = jnp.moveaxis(h_starts, 0, 1)  # [B, NC, H, P, N] (state at chunk start)

    # Inter-chunk contribution: y_inter[t] = exp(cum[t]) * (C_t . h_start)
    y_inter = jnp.einsum("bctn,bchpn->bcthp", Cc, h_starts) * jnp.exp(cum)[..., None]

    y = (y_intra + y_inter).reshape(b, s, h, p)
    return y, h_final


def mamba_forward(
    x: jax.Array, params: dict, cfg: ModelConfig
) -> jax.Array:
    """Full-sequence Mamba2 block. x: [B, S, d_model]."""
    ssm = cfg.ssm
    d_inner, nheads, _ = _dims(cfg)
    b, s, _ = x.shape
    quant = get_quant(cfg)

    proj = quant.dot(x, params["in_proj"], "ssm")
    z, xbc, dt_raw = _split_proj(proj, cfg)
    xbc = _causal_conv(xbc, params["conv_w"], params["conv_b"])
    xin, B, C = jnp.split(xbc, [d_inner, d_inner + ssm.state_dim], axis=-1)

    dt = jax.nn.softplus(dt_raw.astype(jnp.float32) + params["dt_bias"])  # [B,S,H]
    a = -jnp.exp(params["A_log"])[None, None, :] * dt  # log decay
    xh = xin.reshape(b, s, nheads, ssm.head_dim).astype(jnp.float32)

    # Pad sequence to a chunk multiple.
    chunk = min(ssm.chunk_size, s)
    pad = (-s) % chunk
    if pad:
        xh = jnp.pad(xh, ((0, 0), (0, pad), (0, 0), (0, 0)))
        dt = jnp.pad(dt, ((0, 0), (0, pad), (0, 0)))
        a = jnp.pad(a, ((0, 0), (0, pad), (0, 0)))
        B = jnp.pad(B, ((0, 0), (0, pad), (0, 0)))
        C = jnp.pad(C, ((0, 0), (0, pad), (0, 0)))

    y, _ = _ssd_chunked(xh, dt, a, B.astype(jnp.float32), C.astype(jnp.float32), chunk)
    y = y[:, :s]
    y = y + params["D"][None, None, :, None] * xh[:, :s]  # skip connection
    y = y.reshape(b, s, d_inner).astype(x.dtype)
    y = y * jax.nn.silu(z)
    y = rms_norm(y, params["norm_scale"])
    return quant.dot(y, params["out_proj"], "ssm")


def init_mamba_cache(cfg: ModelConfig, batch: int, dtype) -> MambaCache:
    ssm = cfg.ssm
    d_inner, nheads, conv_ch = _dims(cfg)
    return MambaCache(
        conv=jnp.zeros((batch, ssm.conv_width - 1, conv_ch), dtype),
        ssm=jnp.zeros((batch, nheads, ssm.head_dim, ssm.state_dim), jnp.float32),
    )


def mamba_decode(
    x: jax.Array,  # [B, 1, d_model]
    params: dict,
    cfg: ModelConfig,
    cache: MambaCache,
) -> tuple[jax.Array, MambaCache]:
    """Single-token recurrent step."""
    ssm = cfg.ssm
    d_inner, nheads, conv_ch = _dims(cfg)
    b = x.shape[0]
    quant = get_quant(cfg)

    proj = quant.dot(x, params["in_proj"], "ssm")
    z, xbc, dt_raw = _split_proj(proj, cfg)

    # Conv state update: window = [cache.conv, xbc]
    window = jnp.concatenate([cache.conv, xbc[:, 0:1, :]], axis=1)  # [B, K, C]
    w = params["conv_w"]  # [K, C]
    conv_out = jax.nn.silu(jnp.einsum("bkc,kc->bc", window, w) + params["conv_b"])
    new_conv = window[:, 1:, :]

    xin, B, C = jnp.split(conv_out, [d_inner, d_inner + ssm.state_dim], axis=-1)
    dt = jax.nn.softplus(dt_raw[:, 0].astype(jnp.float32) + params["dt_bias"])  # [B,H]
    a = jnp.exp(-jnp.exp(params["A_log"])[None, :] * dt)  # [B,H]
    xh = xin.reshape(b, nheads, ssm.head_dim).astype(jnp.float32)

    h_new = cache.ssm * a[..., None, None] + jnp.einsum(
        "bh,bhp,bn->bhpn", dt, xh, B.astype(jnp.float32)
    )
    y = jnp.einsum("bn,bhpn->bhp", C.astype(jnp.float32), h_new)
    y = y + params["D"][None, :, None] * xh
    y = y.reshape(b, 1, d_inner).astype(x.dtype)
    y = y * jax.nn.silu(z)
    y = rms_norm(y, params["norm_scale"])
    return quant.dot(y, params["out_proj"], "ssm"), MambaCache(conv=new_conv, ssm=h_new)
