"""repro: SystolicAttention reproduction + the jax_pallas scale-out stack.

Importing the package installs the JAX forward-compat shims (see
``repro.compat``) so every module can be written against the modern mesh
API regardless of the jaxlib baked into the host image.
"""

from . import compat as _compat

_compat.ensure()
