"""Elastic mesh rescale: resume any checkpoint on any (valid) mesh shape.

Checkpoints store unsharded logical arrays (see ``repro.checkpoint``);
re-placing them on a different device topology is therefore a pure
sharding decision.  ``rescale_plan`` validates that the model's dimensions
actually divide the new mesh (the failure mode that otherwise surfaces as
an opaque XLA error hours into a resume) and re-derives the full parameter
and optimizer-state sharding trees; ``apply_rescale`` moves a restored
state tree onto the plan's placements.
"""

from __future__ import annotations

import dataclasses
import math
from typing import Any, Optional

import jax

from repro.configs.base import ModelConfig
from .sharding import param_shardings, zero1_shardings


@dataclasses.dataclass
class RescalePlan:
    old_devices: Optional[int]
    new_devices: int
    mesh: Any
    param_shardings: Any
    opt_shardings: Any


def _validate(cfg: ModelConfig, mesh) -> None:
    sizes = {str(a): int(mesh.shape[a]) for a in mesh.axis_names}
    model = sizes.get("model", 1)
    problems = []
    if model > 1:
        if cfg.num_heads % model:
            problems.append(
                f"num_heads={cfg.num_heads} not divisible by model axis {model}"
            )
        if cfg.num_kv_heads % model and cfg.num_heads % model == 0:
            # GQA: KV heads must also split (or be replicated-per-group,
            # which our rules don't do) — reject rather than silently
            # degrade TP to replication on K/V.
            problems.append(
                f"num_kv_heads={cfg.num_kv_heads} not divisible by model axis {model}"
            )
        if cfg.d_ff % model:
            # The MLP gate/up/down projections are the largest dense
            # parameter group; if d_ff can't split, sharding._fit would
            # silently replicate them on every TP rank — reject instead.
            problems.append(
                f"d_ff={cfg.d_ff} not divisible by model axis {model}"
            )
        if cfg.vocab_size % model:
            problems.append(
                f"vocab_size={cfg.vocab_size} not divisible by model axis "
                f"{model} (embedding shards the vocab dim)"
            )
        if cfg.moe is not None and cfg.moe.num_experts % model:
            problems.append(
                f"num_experts={cfg.moe.num_experts} not divisible by "
                f"model axis {model} (expert parallelism)"
            )
    if problems:
        raise ValueError(
            f"mesh {dict(sizes)} incompatible with {cfg.name}: "
            + "; ".join(problems)
        )


def rescale_plan(
    cfg: ModelConfig,
    pshapes: Any,
    oshapes: Any,
    new_mesh,
    *,
    old_devices: Optional[int] = None,
) -> RescalePlan:
    """Derive shardings for resuming on ``new_mesh``; raises ValueError if
    the model cannot be laid out on it."""
    _validate(cfg, new_mesh)
    new_devices = math.prod(int(new_mesh.shape[a]) for a in new_mesh.axis_names)
    return RescalePlan(
        old_devices=old_devices,
        new_devices=new_devices,
        mesh=new_mesh,
        param_shardings=param_shardings(pshapes, cfg, new_mesh),
        opt_shardings=zero1_shardings(oshapes, cfg, new_mesh),
    )


def apply_rescale(state: Any, shardings: Any) -> Any:
    """Place a (restored, host-resident) state tree onto new shardings."""
    return jax.tree.map(
        lambda x, s: jax.device_put(x, s), state, shardings
    )
