"""Fault tolerance: straggler detection, preemption drain, restart loop.

Single-process analogues of the multi-host policies (the trainer wires
them in; ``tests/test_substrate.py`` pins the semantics):

  * ``StepWatchdog`` tracks recent step durations; ``check(dur)`` raises
    ``StragglerDetected`` when a step exceeds ``timeout_factor`` x the
    running median — the signal a multi-host deployment uses to evict a
    slow host rather than let it gate every all-reduce.
  * ``PreemptionHandler`` converts SIGTERM (the cloud preemption notice)
    into a flag the training loop drains at the next step boundary.
  * ``run_with_restarts`` is the supervisor: (re)build state from the
    latest checkpoint and run; on a crash, restart up to ``max_restarts``
    times — combined with atomic checkpoints this makes mid-training node
    failure a bounded-cost event instead of a lost run.

All three emit liveness counters through ``repro.obs``
(``watchdog_heartbeats_total`` / ``watchdog_stragglers_total`` /
``preemptions_total`` / ``restarts_total``) — the saturation signals a
fleet scheduler watches; pass ``registry=`` to scope them, default is the
process-global registry.
"""

from __future__ import annotations

import signal
import statistics
import time
from collections import deque
from typing import Any, Callable, Optional, Tuple

from repro.obs import metrics as _obs_metrics


def _registry(registry):
    """Fault-layer metrics default to the process-global registry so a
    supervisor scraping one endpoint sees every component's health."""
    return registry if registry is not None else _obs_metrics.default_registry()


class StragglerDetected(RuntimeError):
    """A step ran anomalously slow vs. the recent-step median."""


class StepWatchdog:
    def __init__(
        self,
        timeout_factor: float = 5.0,
        warmup_steps: int = 5,
        window: int = 50,
        registry=None,  # repro.obs Registry (default: process-global)
    ):
        self.timeout_factor = timeout_factor
        self.warmup_steps = warmup_steps
        self.durations: deque[float] = deque(maxlen=window)
        self._t0: Optional[float] = None
        reg = _registry(registry)
        self._heartbeats = reg.counter(
            "watchdog_heartbeats_total", "completed steps the watchdog saw"
        )
        self._stragglers = reg.counter(
            "watchdog_stragglers_total", "steps flagged as stragglers"
        )

    def start_step(self) -> None:
        self._t0 = time.monotonic()

    def end_step(self) -> float:
        """Record the step duration (no check — jit compiles on step 0 and
        GC pauses are routine; callers probe explicitly via ``check``)."""
        assert self._t0 is not None, "end_step without start_step"
        dur = time.monotonic() - self._t0
        self._t0 = None
        self.durations.append(dur)
        self._heartbeats.inc()
        return dur

    def median(self) -> Optional[float]:
        if len(self.durations) < max(self.warmup_steps, 1):
            return None
        return statistics.median(self.durations)

    def check(self, duration: float) -> None:
        """Raise StragglerDetected if ``duration`` is anomalous."""
        med = self.median()
        if med is not None and duration > self.timeout_factor * med:
            self._stragglers.inc()
            raise StragglerDetected(
                f"step took {duration:.3f}s vs median {med:.3f}s "
                f"(factor {self.timeout_factor})"
            )


class PreemptionHandler:
    """SIGTERM -> drain flag.  ``install=False`` for tests / nested use."""

    def __init__(self, install: bool = True, signals=(signal.SIGTERM,),
                 registry=None):
        self.requested = False
        self._preemptions = _registry(registry).counter(
            "preemptions_total", "preemption notices received"
        )
        if install:
            for s in signals:
                signal.signal(s, self.trigger)

    def trigger(self, *_args) -> None:
        self.requested = True
        self._preemptions.inc()


def run_with_restarts(
    make_state: Callable[[], Any],
    run_steps: Callable[[Any, int], Any],
    *,
    steps_per_attempt: int,
    max_restarts: int = 3,
    registry=None,
) -> Tuple[Any, int]:
    """Supervise a training run: rebuild state (resume from the latest
    checkpoint) and run; restart on any crash.  Returns
    ``(final_state, restarts_used)``; re-raises after ``max_restarts``."""
    restart_counter = _registry(registry).counter(
        "restarts_total", "supervisor restarts after a crash"
    )
    restarts = 0
    while True:
        state = make_state()
        try:
            return run_steps(state, steps_per_attempt), restarts
        except Exception:
            restarts += 1
            restart_counter.inc()
            if restarts > max_restarts:
                raise
