"""Mesh-aware logical constraint helpers.

``constrain`` is the one entry point model code uses to express layout
intent (Megatron-SP residual sharding, dp_only batch spans, ...).  It is a
*logical* annotation: axis names that don't exist on the ambient mesh are
dropped, dims whose size doesn't divide the named axes are left
unconstrained, and with no ambient mesh at all it is the identity — so the
same model code runs unmodified on a laptop CPU and on a multi-pod slice.
"""

from __future__ import annotations

from typing import Optional, Sequence, Union

import jax
from jax.sharding import PartitionSpec as P

AxisSpec = Union[None, str, Sequence[str]]


def _ambient_mesh():
    mesh = jax.sharding.get_abstract_mesh()
    if mesh is None or getattr(mesh, "empty", False):
        return None
    return mesh


def _ambient_axis_names() -> tuple[str, ...]:
    """Axis names of the mesh currently in scope (() when unsharded)."""
    mesh = _ambient_mesh()
    return tuple(mesh.axis_names) if mesh is not None else ()


def _resolve_entry(entry: AxisSpec, dim_size: int, mesh) -> AxisSpec:
    """Filter one PartitionSpec entry against a concrete mesh."""
    if entry is None:
        return None
    axes = (entry,) if isinstance(entry, str) else tuple(entry)
    axes = tuple(a for a in axes if a in mesh.axis_names)
    if not axes:
        return None
    total = 1
    for a in axes:
        total *= int(mesh.shape[a])
    if total == 1 or dim_size % total != 0:
        return None
    return axes[0] if len(axes) == 1 else axes


def constrain(x: jax.Array, *spec: AxisSpec) -> jax.Array:
    """``with_sharding_constraint`` against the ambient mesh, forgivingly.

    ``spec`` gives one entry per dim of ``x``: an axis name, a tuple of
    axis names (the dim is sharded over their product), or None.  Missing
    trailing entries mean unconstrained.  No-op without an ambient mesh.
    """
    mesh = _ambient_mesh()
    if mesh is None:
        return x
    entries = [
        _resolve_entry(spec[d] if d < len(spec) else None, x.shape[d], mesh)
        for d in range(x.ndim)
    ]
    if all(e is None for e in entries):
        return x
    return jax.lax.with_sharding_constraint(x, P(*entries))


def psum_mean(x: jax.Array, axis_name: str) -> jax.Array:
    """Mean-reduce across one mesh axis (shard_map bodies only)."""
    return jax.lax.psum(x, axis_name) / jax.lax.psum(
        jax.numpy.ones((), x.dtype), axis_name
    )
