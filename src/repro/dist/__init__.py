"""repro.dist — the distributed-execution substrate.

Layers (each usable on its own):

  * ``collectives`` — mesh-aware logical sharding constraints (``constrain``)
    and ambient-mesh introspection used by the model code;
  * ``sharding``    — path-based TP/DP/SP partition rules over the
    ("pod", "data", "model") mesh: params, optimizer state (ZeRO-1),
    batches and KV caches;
  * ``pipeline``    — GPipe-style microbatched stage execution over the
    "pod" axis (``pipelined_apply``);
  * ``elastic``     — checkpoint-portable mesh rescale plans
    (``rescale_plan`` / ``apply_rescale``) with divisibility validation;
  * ``fault``       — step watchdog, preemption drain and restart loop
    (``StepWatchdog``, ``PreemptionHandler``, ``run_with_restarts``).

The mesh convention everywhere: axis "model" carries tensor parallelism,
"data" carries data parallelism (plus ZeRO-1 optimizer-state partitioning
and MoE expert-weight ZeRO-3), "pod" carries either pipeline stages
(``pipeline``) or an extra data-parallel dimension (it folds into DP in
``sharding``'s batch rules).
"""

# NOTE: importing any repro.* module runs repro/__init__.py first, which
# installs the JAX compat shims (repro.compat.ensure) these modules rely on.

from .collectives import constrain  # noqa: F401
from .elastic import RescalePlan, apply_rescale, rescale_plan  # noqa: F401
from .fault import (  # noqa: F401
    PreemptionHandler,
    StepWatchdog,
    StragglerDetected,
    run_with_restarts,
)
from .pipeline import pipelined_apply  # noqa: F401
from .sharding import (  # noqa: F401
    batch_pspec,
    cache_shardings,
    param_pspec,
    param_shardings,
    zero1_shardings,
)

__all__ = [
    "constrain",
    "RescalePlan", "apply_rescale", "rescale_plan",
    "PreemptionHandler", "StepWatchdog", "StragglerDetected",
    "run_with_restarts",
    "pipelined_apply",
    "batch_pspec", "cache_shardings", "param_pspec", "param_shardings",
    "zero1_shardings",
]
