"""Path-based partition rules over the ("pod", "data", "model") mesh.

Conventions (Megatron TP+DP+SP with ZeRO-1 optimizer state):

  * "model" — tensor parallelism.  Column-parallel projections (wq/wk/wv,
    MLP gate/up, SSM in_proj) shard their *output* dim; row-parallel
    projections (wo, MLP down, SSM out_proj) shard their *input* dim;
    embeddings shard the vocab dim; MoE expert banks shard the expert dim
    (expert parallelism — see ``repro.models.moe``).
  * "data" — data parallelism.  Parameters are replicated over it; the
    optimizer state is additionally partitioned over it (ZeRO-1); batches
    shard their leading dim over ("pod", "data").
  * "pod"  — folds into data parallelism here (the pipeline module gives
    it its other meaning).

Every rule is *fitted*: an axis is only emitted when the dim size divides
the axis-size product, so the same rule table serves every architecture in
the registry and any mesh shape — undividable dims degrade to replication
rather than erroring.  ``param_pspec`` is the pure rule function (unit-
testable without devices); the ``*_shardings`` helpers close over a
concrete mesh and return NamedSharding trees for jit in/out_shardings.
"""

from __future__ import annotations

import re
from typing import Any, Optional

import jax
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs.base import ModelConfig

DATA_AXES = ("pod", "data")

# Projections whose output (last) dim is TP-sharded.
_COL_PARALLEL = {
    "wq", "wk", "wv", "bq", "bk", "bv",  # attention QKV (+bias)
    "gate", "up",                        # MLP in-projections
    "in_proj",                           # mamba2
    "wi", "wf", "wz",                    # xLSTM gate in-projections
}
# Projections whose input (second-to-last) dim is TP-sharded.
_ROW_PARALLEL = {"wo", "down", "out_proj"}
# Adafactor factored-stat leaves: strip to reach the param path.
_STAT_LEAVES = {"r", "c", "v"}


def _path_str(path) -> str:
    """tree_util key path -> "a/b/c" (dict keys only; tuple indices kept)."""
    return "/".join(str(getattr(p, "key", getattr(p, "idx", p))) for p in path)


def _fit(entry, dim_size: int, sizes: dict[str, int]):
    """Keep an axis group only if every axis exists and the product divides."""
    if entry is None:
        return None
    axes = (entry,) if isinstance(entry, str) else tuple(entry)
    axes = tuple(a for a in axes if sizes.get(a, 0) > 1)
    total = 1
    for a in axes:
        total *= sizes[a]
    if not axes or dim_size % total != 0:
        return None
    return axes[0] if len(axes) == 1 else axes


def param_pspec(
    path: str,
    shape: tuple[int, ...],
    cfg: ModelConfig,
    data_size: int,
    model_size: int,
) -> P:
    """Partition spec for one parameter leaf, identified by its tree path.

    ``path`` is the "/"-joined key path (e.g. "layers/attn/wq").  Stacked
    layer params carry a leading scan dim which is never sharded; the rules
    therefore address dims from the *trailing* end.  Dims that don't divide
    the proposed axes are left replicated.
    """
    sizes = {"data": data_size, "model": model_size}
    parts = [p for p in re.split(r"[./]", path) if p]
    name = parts[-1] if parts else ""
    if name in _STAT_LEAVES and len(parts) > 1:  # adafactor r/c/v stats
        name = parts[-2]
    rank = len(shape)
    spec: list[Any] = [None] * rank

    if rank == 0:
        return P()
    if name == "embed":
        spec[0] = "model"  # vocab dim
    elif name == "lm_head":
        spec[rank - 1] = "model"  # [d, V]
    elif parts and "moe" in parts and name in ("gate", "up", "down") and rank >= 3:
        spec[rank - 3] = "model"  # expert dim: EP
    elif name == "router":
        pass  # replicated (fp32, tiny, read by every rank)
    elif name in _COL_PARALLEL and rank >= 1:
        spec[rank - 1] = "model"
    elif name in _ROW_PARALLEL and rank >= 2:
        spec[rank - 2] = "model"

    spec = [_fit(e, shape[d], sizes) for d, e in enumerate(spec)]
    return P(*spec)


def _mesh_sizes(mesh) -> dict[str, int]:
    return {str(a): int(mesh.shape[a]) for a in mesh.axis_names}


def param_shardings(pshapes: Any, cfg: ModelConfig, mesh) -> Any:
    """NamedSharding tree for the parameters (TP over "model")."""
    sizes = _mesh_sizes(mesh)
    data, model = sizes.get("data", 1), sizes.get("model", 1)

    def one(path, leaf):
        spec = param_pspec(_path_str(path), tuple(leaf.shape), cfg, data, model)
        return NamedSharding(mesh, spec)

    return jax.tree_util.tree_map_with_path(one, pshapes)


def zero1_shardings(oshapes: Any, cfg: ModelConfig, mesh) -> Any:
    """Optimizer-state shardings: the param's TP layout plus a ZeRO-1
    partition — the first still-replicated divisible dim of every stat is
    sharded over "data", so AdamW moments / Adafactor factors never cost
    replicated-parameter memory on the DP axis."""
    sizes = _mesh_sizes(mesh)
    data, model = sizes.get("data", 1), sizes.get("model", 1)

    def one(path, leaf):
        shape = tuple(leaf.shape)
        spec = list(param_pspec(_path_str(path), shape, cfg, data, model))
        if data > 1:
            for d in range(len(shape)):
                if spec[d] is None and shape[d] % data == 0 and shape[d] >= data:
                    spec[d] = "data"
                    break
        return NamedSharding(mesh, P(*spec))

    return jax.tree_util.tree_map_with_path(one, oshapes)


def batch_pspec(batch: Any, mesh, cfg: Optional[ModelConfig] = None) -> Any:
    """Batch shardings: leading (global-batch) dim over every data axis
    present on the mesh; scalars replicated."""
    del cfg  # uniform across archs — kept for call-site symmetry
    sizes = _mesh_sizes(mesh)
    daxes = tuple(a for a in DATA_AXES if sizes.get(a, 0) > 1)

    def one(leaf):
        shape = tuple(leaf.shape)
        entry = _fit(daxes, shape[0], sizes) if shape else None
        if entry is None:
            return NamedSharding(mesh, P())
        return NamedSharding(mesh, P(entry, *([None] * (len(shape) - 1))))

    return jax.tree.map(one, batch)


def cache_shardings(cache: Any, cfg: ModelConfig, mesh) -> Any:
    """KV/state-cache shardings.  Every stacked cache leaf is
    [num_layers, batch, ...] — batch at dim 1 (the invariant
    ``repro.models.insert_cache`` slots into): the batch dim shards over
    the data axes, so each continuous-batching slot lives on one DP shard
    and slot insert/retire touches a single replica group.  Floating
    KV/state tensors [L, B, S, H, d] additionally shard the head dim over
    "model" (matching the column-parallel K/V projections that fill them).
    Integer leaves — the per-slot ``lengths`` [L, B] that drive decode
    scatter offsets and masks — only ever shard the batch dim.

    int8 KV caches need their own rule: the payloads are integer (the
    floating check above would leave them replicated) and the per-token
    scales [L, B, S, Hkv] would have their *sequence* dim matched by the
    generic rank-2-from-the-end rule.  Both shard the head dim (3) over
    "model", keeping payload and scale coscharded with the column-parallel
    K/V projections that fill them."""
    from repro.models.attention import QuantKVCache  # lazy: models import dist

    sizes = _mesh_sizes(mesh)
    daxes = tuple(a for a in DATA_AXES if sizes.get(a, 0) > 1)

    def spec_for(shape, model_dim=None):
        rank = len(shape)
        spec: list[Any] = [None] * rank
        if rank >= 2:
            spec[1] = _fit(daxes, shape[1], sizes)
        if model_dim is not None and rank > model_dim:
            spec[model_dim] = _fit("model", shape[model_dim], sizes)
        return NamedSharding(mesh, P(*spec))

    def one(leaf):
        shape = tuple(leaf.shape)
        rank = len(shape)
        floating = jax.numpy.issubdtype(leaf.dtype, jax.numpy.floating)
        return spec_for(shape, rank - 2 if rank >= 4 and floating else None)

    def node(x):
        if isinstance(x, QuantKVCache):
            return QuantKVCache(
                k=spec_for(tuple(x.k.shape), 3),
                v=spec_for(tuple(x.v.shape), 3),
                k_scale=spec_for(tuple(x.k_scale.shape), 3),
                v_scale=spec_for(tuple(x.v_scale.shape), 3),
                lengths=spec_for(tuple(x.lengths.shape)),
            )
        return jax.tree.map(one, x)

    return jax.tree.map(node, cache, is_leaf=lambda x: isinstance(x, QuantKVCache))
