"""GPipe-style pipeline parallelism over the "pod" mesh axis.

``pipelined_apply`` runs a stack of identical stages (stage s owns
``stage_params[s]``) over a batch split into microbatches.  On a mesh with
a "pod" axis of size ``num_stages`` it executes as a real rotating
pipeline under ``shard_map``: each device holds exactly one stage's
weights, activations advance one stage per tick via ``ppermute``, and the
schedule drains in ``num_microbatches + num_stages - 1`` ticks (the GPipe
bubble).  Off-mesh (or when the mesh doesn't match) it falls back to the
numerically identical sequential schedule, so the same call works in unit
tests and on a single host.
"""

from __future__ import annotations

from typing import Any, Callable

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from .collectives import _ambient_mesh

AXIS = "pod"


def _stage_slice(stage_params: Any, i) -> Any:
    return jax.tree.map(lambda w: w[i], stage_params)


def _sequential(stage_fn, stage_params, x, num_stages):
    for i in range(num_stages):
        x = stage_fn(_stage_slice(stage_params, i), x)
    return x


def pipelined_apply(
    stage_fn: Callable[[Any, jax.Array], jax.Array],
    stage_params: Any,  # pytree; every leaf has leading dim num_stages
    x: jax.Array,  # [B, ...] activations entering stage 0
    *,
    num_stages: int,
    num_microbatches: int,
) -> jax.Array:
    """Apply ``num_stages`` stages in sequence, pipelined over "pod"."""
    mesh = _ambient_mesh()
    pipelined = (
        mesh is not None
        and AXIS in mesh.axis_names
        and int(mesh.shape[AXIS]) == num_stages
        and num_stages > 1
        and x.shape[0] % num_microbatches == 0
    )
    if not pipelined:
        return _sequential(stage_fn, stage_params, x, num_stages)

    mb = x.shape[0] // num_microbatches
    x_mb = x.reshape(num_microbatches, mb, *x.shape[1:])
    shift_fwd = [(i, (i + 1) % num_stages) for i in range(num_stages)]

    def local_fn(w_local, x_all):
        # w_local: this stage's slice (leading dim 1); x_all: replicated
        # [M, mb, ...] microbatches.
        stage = jax.lax.axis_index(AXIS)
        w = _stage_slice(w_local, 0)
        acc = jnp.zeros_like(x_all)
        recv = jnp.zeros(x_all.shape[1:], x_all.dtype)
        for t in range(num_microbatches + num_stages - 1):
            # Stage 0 injects microbatch t (it idles on a replay of the
            # last microbatch once the feed is exhausted — the result is
            # discarded); every other stage consumes last tick's send.
            feed = x_all[min(t, num_microbatches - 1)]
            y = stage_fn(w, jnp.where(stage == 0, feed, recv))
            m_out = t - (num_stages - 1)
            if 0 <= m_out < num_microbatches:
                acc = jnp.where(stage == num_stages - 1, acc.at[m_out].set(y), acc)
            recv = jax.lax.ppermute(y, AXIS, shift_fwd)
        # Only the last stage accumulated real outputs; psum replicates
        # them to every stage (all other contributions are zero).
        return jax.lax.psum(acc, AXIS)

    w_specs = jax.tree.map(
        lambda w: P(AXIS, *([None] * (w.ndim - 1))), stage_params
    )
    x_spec = P(*([None] * x_mb.ndim))
    out = jax.shard_map(
        local_fn,
        in_specs=(w_specs, x_spec),
        out_specs=x_spec,
    )(stage_params, x_mb)
    return out.reshape(x.shape[0], *x.shape[1:])
