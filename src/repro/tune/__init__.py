"""repro.tune — mesh-parallel FSA design-space autotuner.

The paper publishes one design point (128x128 array, dual-direction
schedule, 8-segment PWL exp2, 192+64 KiB SRAM, 1.5 GHz); this subsystem
explores the whole space around it:

  * ``design``     — frozen, hashable ``DesignPoint`` with Table 1
                     capacity validation;
  * ``objectives`` — utilization/TFLOPs (systolic_model closed forms),
                     Table 2 accuracy (fsa_sim-equivalent vectorized
                     numerics) and Table 3 area, each cross-checked
                     against the paper's numbers at the paper's point;
  * ``search``     — grid sweep sharded over the device mesh, random
                     search, successive halving (deterministic seeding);
  * ``pareto``     — non-dominated frontier over (TFLOP/s, area, error);
  * ``report``     — ``run_tune`` + markdown / ``BENCH_tune.json`` output
                     (``python -m repro.launch.tune``).
"""

from .design import (  # noqa: F401
    DesignPoint,
    accum_required_bytes,
    exact_fit_point,
    paper_point,
    spad_required_bytes,
)
from .objectives import (  # noqa: F401
    PAPER_TARGETS,
    eval_accuracy,
    eval_area,
    eval_performance,
    evaluate,
    quantized_systolic_attention,
)
from .pareto import OBJECTIVES, dominates, pareto_front  # noqa: F401
from .report import PRESETS, render_markdown, run_tune, write_report  # noqa: F401
from .search import (  # noqa: F401
    SweepResult,
    encode_points,
    grid_space,
    grid_sweep,
    random_search,
    scalar_score,
    successive_halving,
    tune_mesh,
)

__all__ = [
    "DesignPoint", "paper_point", "exact_fit_point",
    "spad_required_bytes", "accum_required_bytes",
    "PAPER_TARGETS", "evaluate", "eval_performance", "eval_accuracy",
    "eval_area", "quantized_systolic_attention",
    "OBJECTIVES", "pareto_front", "dominates",
    "SweepResult", "tune_mesh", "encode_points", "grid_space", "grid_sweep",
    "random_search", "successive_halving", "scalar_score",
    "PRESETS", "run_tune", "render_markdown", "write_report",
]
