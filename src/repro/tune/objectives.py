"""Objective evaluators for FSA design points.

Three objectives per point, each reusing the repo's paper-reproduction
machinery and cross-checked against the paper's published numbers at the
paper's design point (see ``PAPER_TARGETS`` and ``tests/test_tune.py``):

  * **performance** — mean attention FLOPs/s utilization over the Fig. 11
    sequence sweep from ``core.systolic_model`` (closed-form §3.5 cycle
    counts), achieved TFLOP/s at the point's clock, and mean speedup vs
    the modelled TPUv5e / NeuronCore-v2 baselines (paper: 1.77x / 4.83x);
  * **accuracy** — end-to-end FlashAttention error on the Table 2 input
    distribution through ``quantized_systolic_attention``, a vectorized
    numpy twin of the instruction-level ``fsa_sim`` arithmetic (fp16
    operands/activations, fp32 accumulation, the point's PWL exp2) — the
    twin is asserted bit-compatible with ``fsa_flash_attention`` in the
    tests — plus the apparatus-independent Fig. 12 PWL exp2 error
    (exhaustive over negative normal fp16, MRE 2.728e-2 at 8 segments);
  * **area** — the Table 3 component model generalized over the design
    axes: per-PE / upward-path / split-unit areas scale with N^2, the CMP
    row with N, the split-unit LUT share with the segment count, logic
    area with the clock target, plus an SRAM estimate for the scratchpad
    and accumulation capacities.  At the paper point it reproduces
    Table 3 exactly (28,157,816 um^2 array total, 12.07% overhead).

Note on Table 2 absolute errors: our simulator (and therefore this twin)
keeps fp32 inter-PE partial sums, where the paper's RTL quantizes more
aggressively, so our MAE is *smaller* than the paper's (6.5e-5 vs 7.98e-3
at seq 2048); the paper's error envelope (MAE <= 3.4e-2, MRE <= 7.2e-2)
is the bound that transfers, and the Fig. 12 PWL error is the sharp
8-segment cross-check.
"""

from __future__ import annotations

import dataclasses
import functools

import numpy as np

from repro.core.pwl_exp2 import LOG2_E, pwl_error_stats, segment_table
from repro.core.systolic_model import (
    PAPER_SEQLENS,
    attention_flops,
    baseline_utilization,
    fsa_attention_cycles,
    fsa_utilization,
)

from .design import DesignPoint

__all__ = [
    "PAPER_TARGETS",
    "quantized_systolic_attention",
    "eval_performance",
    "eval_accuracy",
    "eval_area",
    "evaluate",
]

# Published numbers the evaluators must land on at the paper's design point.
PAPER_TARGETS = {
    "speedup_vs_tpu_v5e": 1.77,      # Fig. 11
    "speedup_vs_neuron_v2": 4.83,    # Fig. 11
    "area_total_um2": 28_157_816.0,  # Table 3 (sum of all components)
    "overhead_pct": 12.07,           # Table 3
    "pwl_mre_8seg": 0.02728,         # Fig. 12, 8 segments
    "table2_mae_envelope": 3.40e-2,  # Table 2 worst MAE (seq 16384)
    "table2_mre_envelope": 7.20e-2,  # Table 2 worst MRE
}

# ---------------------------------------------------------------------------
# Performance (core.systolic_model closed forms)
# ---------------------------------------------------------------------------

def eval_performance(point: DesignPoint, seqlens=PAPER_SEQLENS) -> dict:
    """Mean utilization / TFLOP/s / baseline speedups at head_dim = N."""
    n = point.array_n
    utils = [
        fsa_utilization(s, n, n, single_direction=point.single_direction)
        for s in seqlens
    ]
    mean_util = float(np.mean(utils))
    peak_tflops = point.peak_flops_per_cycle * point.freq_ghz * 1e9 / 1e12
    base = {
        which: float(np.mean([baseline_utilization(which, s, n) for s in seqlens]))
        for which in ("tpu_v5e", "neuron_v2")
    }
    return {
        "mean_util": mean_util,
        "mean_tflops": mean_util * peak_tflops,
        "peak_tflops": peak_tflops,
        "speedup_vs_tpu_v5e": mean_util / base["tpu_v5e"],
        "speedup_vs_neuron_v2": mean_util / base["neuron_v2"],
        "cycles_max_seq": fsa_attention_cycles(
            max(seqlens), n, n, single_direction=point.single_direction
        ),
        "flops_max_seq": attention_flops(max(seqlens), n),
    }


# ---------------------------------------------------------------------------
# Accuracy (Table 2 protocol through the fsa_sim-equivalent numpy twin)
# ---------------------------------------------------------------------------

def quantized_systolic_attention(
    q: np.ndarray,  # [seq, d] fp16
    k: np.ndarray,  # [seq, d] fp16
    v: np.ndarray,  # [seq, d] fp16
    *,
    array_n: int,
    num_segments: int,
) -> np.ndarray:
    """Vectorized twin of the ``fsa_sim`` AttnScore/AttnValue arithmetic.

    Identical op order and precision to ``FSADevice._op_attn_score`` /
    ``_op_attn_value`` — fp16 S leaving the array top, fp16 P resident in
    the PEs, fp32 accumulation, PWL exp2 on fp32 MACs — but evaluated for
    all Q rows at once instead of per instruction, so a seq-2048 Table 2
    measurement takes ~0.7 s instead of minutes.
    """
    seq, d = q.shape
    assert seq % array_n == 0, (seq, array_n)
    slope, intercept = segment_table(num_segments)

    def pwl(x32: np.ndarray) -> np.ndarray:
        x_i = np.ceil(x32)
        x_f = x32 - x_i
        idx = np.clip(
            np.floor((x_f + 1.0) * num_segments).astype(np.int32),
            0, num_segments - 1,
        )
        frac = slope[idx] * x_f + intercept[idx]
        out = np.ldexp(frac, np.clip(x_i, -150, 127).astype(np.int32))
        out[x_i < -148] = 0.0
        return out.astype(np.float32)

    scale = 1.0 / float(np.sqrt(d))
    c = np.float16(scale * LOG2_E)
    qt = np.ascontiguousarray(q.T)  # [d, seq], stationary layout
    vt = np.ascontiguousarray(v.T)  # [d, seq]
    old_m = np.full((seq,), -np.inf, np.float32)
    l_acc = np.zeros((seq,), np.float32)
    o_acc = np.zeros((d, seq), np.float32)
    for j0 in range(0, seq, array_n):
        kt = k[j0 : j0 + array_n].astype(np.float32)  # [Bc, d]
        s = (kt @ qt.astype(np.float32)).astype(np.float16)  # [Bc, seq]
        local_m = s.max(axis=0)
        new_m = np.maximum(local_m, old_m.astype(np.float16))
        a = np.maximum((old_m.astype(np.float16) - new_m).astype(np.float32), -1e4)
        b = pwl(np.float32(c) * a)
        n_mat = (s - new_m[None, :]).astype(np.float16)
        p = pwl((c * n_mat).astype(np.float32)).astype(np.float16)
        l_acc = l_acc * b + p.astype(np.float32).sum(axis=0)
        o_acc = o_acc * b[None, :] + vt[:, j0 : j0 + array_n].astype(np.float32) @ p.astype(
            np.float32
        )
        old_m = new_m.astype(np.float32)
    recip = np.where(l_acc == 0, 0.0, 1.0 / l_acc).astype(np.float32)
    return np.ascontiguousarray((o_acc * recip[None, :]).T)


def _draw_table2(rng: np.random.Generator, shape) -> np.ndarray:
    """The paper's Table 2 heavy-tail input distribution (FA-3 protocol)."""
    x = rng.standard_normal(shape) + rng.standard_normal(shape) * 10.0 * (
        rng.random(shape) < 0.001
    )
    return x.astype(np.float16)


@functools.lru_cache(maxsize=None)
def _accuracy_cached(array_n: int, num_segments: int, seq: int, seed: int) -> dict:
    rng = np.random.default_rng((seed, array_n, seq))
    shape = (seq, array_n)  # FSA maps head_dim = N (paper §3.5)
    q, k, v = (_draw_table2(rng, shape) for _ in range(3))
    approx = quantized_systolic_attention(
        q, k, v, array_n=array_n, num_segments=num_segments
    ).astype(np.float64)
    qf, kf, vf = (a.astype(np.float64) for a in (q, k, v))
    s = qf @ kf.T / np.sqrt(array_n)
    p = np.exp(s - s.max(-1, keepdims=True))
    p /= p.sum(-1, keepdims=True)
    exact = p @ vf
    diff = np.abs(approx - exact)
    return {
        "acc_mae": float(diff.mean()),
        "acc_mre": float((diff / (np.abs(exact) + 1e-9)).mean()),
        "acc_seq": float(seq),
    }


@functools.lru_cache(maxsize=None)
def _pwl_stats_cached(num_segments: int) -> tuple[float, float]:
    stats = pwl_error_stats(num_segments)
    return stats["mae"], stats["mre"]


def eval_accuracy(point: DesignPoint, *, seq: int = 2048, seed: int = 0) -> dict:
    """Table 2 end-to-end error + Fig. 12 PWL intrinsic error.

    ``seq`` is rounded up to a multiple of the array size (tile
    granularity); results are cached per (N, segments, seq, seed) — the
    objective depends only on those axes, so grid sweeps pay for each
    distinct combination once.
    """
    n = point.array_n
    seq = -(-seq // n) * n
    out = dict(_accuracy_cached(n, point.pwl_segments, seq, seed))
    pwl_mae, pwl_mre = _pwl_stats_cached(point.pwl_segments)
    out["pwl_mae"] = pwl_mae
    out["pwl_mre"] = pwl_mre
    return out


# ---------------------------------------------------------------------------
# Area (Table 3 component model, generalized)
# ---------------------------------------------------------------------------

PAPER_N = 128
# Paper Table 3 component areas at N = 128, 16 nm, 1.5 GHz (um^2).
PAPER_AREA = {
    "pes": 24_445_044.0,
    "other": 313_457.0,
    "upward": 1_756_641.0,
    "split": 1_493_150.0,
    "cmp": 149_524.0,
}
# Share of the split unit that is the PWL coefficient LUT (scales with the
# segment count; the splitter/MAC half does not).  Estimate — chosen so the
# 8-segment point reproduces Table 3 exactly and the area cost of segment
# count is visible to the tuner.
SPLIT_LUT_FRACTION = 0.5
# 16 nm SRAM density estimate incl. periphery: ~0.15 um^2/bit.
SRAM_UM2_PER_KIB = 1200.0
# Logic area vs synthesis clock: relative slope per GHz around the paper's
# 1.5 GHz target (larger drive strengths at tighter timing).  Estimate.
FREQ_AREA_SLOPE = 0.15


def eval_area(point: DesignPoint) -> dict:
    """Generalized Table 3 accounting: array logic + SRAM estimate."""
    n = point.array_n
    per_pe = PAPER_AREA["pes"] / (PAPER_N * PAPER_N)
    per_up = PAPER_AREA["upward"] / (PAPER_N * PAPER_N)
    per_split = PAPER_AREA["split"] / (PAPER_N * PAPER_N)
    per_cmp = PAPER_AREA["cmp"] / PAPER_N

    freq_scale = 1.0 + FREQ_AREA_SLOPE * (point.freq_ghz - 1.5)
    std = (per_pe * n * n + PAPER_AREA["other"]) * freq_scale
    split = per_split * n * n * (
        1.0 - SPLIT_LUT_FRACTION + SPLIT_LUT_FRACTION * point.pwl_segments / 8.0
    )
    upward = 0.0 if point.single_direction else per_up * n * n
    add = (split + upward + per_cmp * n) * freq_scale
    sram = (point.spad_kib + point.accum_kib) * SRAM_UM2_PER_KIB
    return {
        "std_um2": std,
        "fsa_additional_um2": add,
        "array_um2": std + add,
        "sram_um2": sram,
        "total_um2": std + add + sram,
        "overhead_pct": 100.0 * add / (std + add),
    }


# ---------------------------------------------------------------------------
# Full record
# ---------------------------------------------------------------------------

def evaluate(point: DesignPoint, *, accuracy_seq: int = 2048, seed: int = 0) -> dict:
    """All objectives for one point, as a flat record (point fields included)."""
    point.validate()
    rec = {"label": point.label(), **dataclasses.asdict(point)}
    rec.update(eval_performance(point))
    rec.update(eval_area(point))
    rec.update(eval_accuracy(point, seq=accuracy_seq, seed=seed))
    return rec
