"""Regenerable Pareto report: sweep -> frontier -> paper checks -> markdown.

``run_tune`` drives the whole subsystem: build a space (preset or custom),
evaluate it (mesh-sharded), extract the Pareto frontier, verify the
evaluators against the paper's published numbers at the paper's design
point, and spot-check frontier points end-to-end through the
instruction-level ``fsa_sim`` (cycle counts must equal the §3.5 closed
forms; numerics must stay inside the Table 2 envelope).  Everything is
deterministic given the seed — running twice produces byte-identical
JSON, so CI can regenerate and diff the report.

The special case ``preset="paper"`` evaluates exactly the paper's design
point, i.e. reproduces Fig. 11 / Table 2 / Table 3 on their own.
"""

from __future__ import annotations

import json
from typing import Optional

import numpy as np

from repro.core.fsa_flash import fsa_flash_attention
from repro.core.systolic_model import fsa_attention_cycles

from .design import DesignPoint, paper_point
from .objectives import PAPER_TARGETS, evaluate
from .pareto import OBJECTIVES, attach_frontier
from .search import (
    SweepResult,
    grid_space,
    grid_sweep,
    random_search,
    scalar_score,
    successive_halving,
    tune_mesh,
)

__all__ = ["PRESETS", "run_tune", "render_markdown", "write_report"]

# Grid axes + Table 2 protocol length per preset.  "paper" is the paper's
# single published point; "smoke" is the CI-sized sweep; "full" is the
# whole modelled space.
PRESETS = {
    "paper": dict(
        array_ns=(128,), schedules=("standard",), segments=(8,),
        sram_overs=(1,), freqs=(1.5,), accuracy_seq=2048,
    ),
    "smoke": dict(
        array_ns=(64, 128), schedules=("standard", "single_direction"),
        segments=(4, 8), sram_overs=(1,), freqs=(1.5,), accuracy_seq=256,
    ),
    "ci": dict(
        array_ns=(64, 128, 256), schedules=("standard", "single_direction"),
        segments=(4, 8, 16), sram_overs=(1, 2), freqs=(1.0, 1.5),
        accuracy_seq=512,
    ),
    "full": dict(
        array_ns=(32, 64, 128, 256), schedules=("standard", "single_direction"),
        segments=(2, 4, 8, 16, 32), sram_overs=(1, 2),
        freqs=(0.75, 1.0, 1.5, 2.0), accuracy_seq=2048,
    ),
}


def _paper_checks(accuracy_seq: int) -> tuple[dict, dict]:
    """Evaluate the paper point and compare against the published numbers."""
    rec = evaluate(paper_point(), accuracy_seq=accuracy_seq)
    t = PAPER_TARGETS

    def rel_ok(value, target, tol):
        return abs(value - target) <= tol * abs(target)

    checks = {
        "fig11_speedup_vs_tpu_v5e": {
            "value": rec["speedup_vs_tpu_v5e"], "target": t["speedup_vs_tpu_v5e"],
            "ok": rel_ok(rec["speedup_vs_tpu_v5e"], t["speedup_vs_tpu_v5e"], 0.02),
        },
        "fig11_speedup_vs_neuron_v2": {
            "value": rec["speedup_vs_neuron_v2"], "target": t["speedup_vs_neuron_v2"],
            "ok": rel_ok(rec["speedup_vs_neuron_v2"], t["speedup_vs_neuron_v2"], 0.02),
        },
        "table3_array_total_um2": {
            "value": rec["array_um2"], "target": t["area_total_um2"],
            "ok": rel_ok(rec["array_um2"], t["area_total_um2"], 1e-3),
        },
        "table3_overhead_pct": {
            "value": rec["overhead_pct"], "target": t["overhead_pct"],
            "ok": abs(rec["overhead_pct"] - t["overhead_pct"]) < 0.1,
        },
        "fig12_pwl_mre_8seg": {
            "value": rec["pwl_mre"], "target": t["pwl_mre_8seg"],
            "ok": rel_ok(rec["pwl_mre"], t["pwl_mre_8seg"], 0.05),
        },
        # Our simulator keeps fp32 inter-PE partial sums (the RTL quantizes
        # harder), so absolute Table 2 errors are smaller than the paper's;
        # the paper's worst-case envelope is the transferable bound.
        "table2_mae_envelope": {
            "value": rec["acc_mae"], "target": t["table2_mae_envelope"],
            "ok": rec["acc_mae"] <= t["table2_mae_envelope"],
        },
        "table2_mre_envelope": {
            "value": rec["acc_mre"], "target": t["table2_mre_envelope"],
            "ok": rec["acc_mre"] <= t["table2_mre_envelope"],
        },
    }
    return rec, checks


def _sim_cross_checks(records: list[dict], count: int) -> list[dict]:
    """Run >= ``count`` frontier points through the instruction-level sim.

    Validates the analytical model end to end: the simulated Listing-2
    kernel's cycle count must equal the §3.5 closed form for the point's
    array size and schedule variant, and its output must stay inside the
    Table 2 error envelope.
    """
    ordered = sorted(records, key=lambda r: (not r["on_frontier"], r["label"]))
    seen: set[tuple] = set()
    picked = []
    for rec in ordered:
        key = (rec["array_n"], rec["schedule"], rec["pwl_segments"])
        if key in seen:
            continue
        seen.add(key)
        picked.append(rec)
        if len(picked) >= count:
            break

    out = []
    for rec in picked:
        n = int(rec["array_n"])
        seq = 2 * n  # Tr = Tc = 2: exercises inner loop, rescale and drain
        single = rec["schedule"] == "single_direction"
        rng = np.random.default_rng((7, n, int(rec["pwl_segments"])))
        q, k, v = (rng.standard_normal((seq, n)).astype(np.float16) for _ in range(3))
        res = fsa_flash_attention(
            q, k, v,
            array_n=n,
            num_segments=int(rec["pwl_segments"]),
            single_direction=single,
            spad_bytes=int(rec["spad_kib"]) * 1024,
            # +4N B: the l row shares the sim's accum space but is held in
            # accumulator registers in the Table 1 capacity accounting.
            accum_bytes=int(rec["accum_kib"]) * 1024 + 4 * n,
        )
        model = fsa_attention_cycles(seq, n, n, single_direction=single)
        qf, kf, vf = (a.astype(np.float64) for a in (q, k, v))
        s = qf @ kf.T / np.sqrt(n)
        p = np.exp(s - s.max(-1, keepdims=True))
        p /= p.sum(-1, keepdims=True)
        mae = float(np.abs(res.output - p @ vf).mean())
        out.append(
            {
                "label": rec["label"],
                "seq": seq,
                "cycles_sim": int(res.cycles),
                "cycles_model": int(model),
                "cycles_ok": int(res.cycles) == int(model),
                "mae": mae,
                "mae_ok": mae <= PAPER_TARGETS["table2_mae_envelope"],
                "on_frontier": bool(rec["on_frontier"]),
            }
        )
    return out


def run_tune(
    preset: str = "smoke",
    *,
    search: str = "grid",
    seed: int = 0,
    mesh=True,
    num_points: int = 32,
    accuracy_seq: Optional[int] = None,
    paper_check_seq: int = 2048,
    sim_check_count: int = 3,
) -> dict:
    """Full autotune pass; returns the report payload (JSON-serializable)."""
    spec = dict(PRESETS[preset])
    acc_seq = accuracy_seq if accuracy_seq is not None else spec.pop("accuracy_seq")
    spec.pop("accuracy_seq", None)

    if mesh is True:
        mesh = tune_mesh()
    elif mesh is False:
        mesh = None
    ndev = int(mesh.shape["tune"]) if mesh is not None else 1

    if search == "grid":
        points = grid_space(**spec)
        result: SweepResult = grid_sweep(
            points, mesh=mesh, accuracy_seq=acc_seq, seed=seed
        )
    elif search == "random":
        result = random_search(
            num_points, seed=seed, mesh=mesh, accuracy_seq=acc_seq,
            array_ns=spec["array_ns"], schedules=spec["schedules"],
            segments=spec["segments"], sram_overs=spec["sram_overs"],
            freqs=spec["freqs"],
        )
    elif search == "sha":
        points = grid_space(**spec)
        fidelities = tuple(sorted({min(256, acc_seq), max(acc_seq // 2, 256), acc_seq}))
        result = successive_halving(
            points, seed=seed, mesh=mesh, fidelities=fidelities
        )
    else:
        raise ValueError(f"unknown search driver: {search!r}")

    records = result.records
    front = attach_frontier(records)
    paper_rec, checks = _paper_checks(paper_check_seq)

    # Where does the paper's point sit?  (It is in every grid preset; for
    # random/sha it may not have been sampled.)
    paper_label = paper_point().label()
    swept_paper = next((r for r in records if r["label"] == paper_label), None)
    paper_on_frontier = bool(swept_paper and swept_paper["on_frontier"])

    sim_checks = _sim_cross_checks(records, sim_check_count)

    frontier = sorted(
        (records[i] for i in front), key=lambda r: -r["mean_tflops"]
    )
    return {
        "preset": preset,
        "search": search,
        "seed": seed,
        "accuracy_seq": acc_seq,
        "mesh_devices": ndev,
        "per_device_counts": result.per_device_counts,
        "num_points": len(records),
        "frontier_size": len(front),
        "paper_point_in_sweep": swept_paper is not None,
        "paper_on_frontier": paper_on_frontier,
        "paper": {
            k: paper_rec[k]
            for k in (
                "mean_util", "mean_tflops", "speedup_vs_tpu_v5e",
                "speedup_vs_neuron_v2", "array_um2", "total_um2",
                "overhead_pct", "acc_mae", "acc_mre", "pwl_mae", "pwl_mre",
            )
        },
        "paper_checks": checks,
        "paper_checks_ok": all(c["ok"] for c in checks.values()),
        "sim_checks": sim_checks,
        "sim_checks_ok": bool(sim_checks)
        and all(c["cycles_ok"] and c["mae_ok"] for c in sim_checks),
        "objectives": [list(o) for o in OBJECTIVES],
        "frontier": frontier,
        "records": records,
    }


# ---------------------------------------------------------------------------
# Rendering
# ---------------------------------------------------------------------------

def _fmt(v, nd=3):
    if isinstance(v, bool):
        return "yes" if v else "no"
    if isinstance(v, float):
        if v == 0:
            return "0"
        if abs(v) >= 1e5 or abs(v) < 1e-3:
            return f"{v:.2e}"
        return f"{v:.{nd}f}"
    return str(v)


def render_markdown(report: dict) -> str:
    lines = [
        "# FSA design-space autotune report",
        "",
        f"- preset `{report['preset']}`, search `{report['search']}`, "
        f"seed {report['seed']}, Table 2 protocol seq {report['accuracy_seq']}",
        f"- {report['num_points']} design points over "
        f"{report['mesh_devices']} device(s); per-device shard counts "
        f"{report['per_device_counts']}",
        f"- Pareto objectives: "
        + ", ".join(f"{k} ({d})" for k, d in report["objectives"]),
        "",
        "## Paper design point vs published numbers",
        "",
        "| check | value | paper | ok |",
        "|---|---|---|---|",
    ]
    for name, c in report["paper_checks"].items():
        lines.append(
            f"| {name} | {_fmt(float(c['value']))} | {_fmt(float(c['target']))} "
            f"| {_fmt(bool(c['ok']))} |"
        )
    where = (
        "on the Pareto frontier"
        if report["paper_on_frontier"]
        else "NOT on the frontier"
        if report["paper_point_in_sweep"]
        else "not in this sweep"
    )
    lines += [
        "",
        f"The paper's 128x128 / 8-segment / 192+64 KiB point is **{where}** "
        "of this sweep.",
        "",
        "## Pareto frontier",
        "",
        "| design | util | TFLOP/s | area mm^2 | overhead % | Table2 MRE "
        "| vs TPUv5e | vs Neuron-v2 |",
        "|---|---|---|---|---|---|---|---|",
    ]
    paper_label = paper_point().label()
    for r in report["frontier"]:
        star = " *" if r["label"] == paper_label else ""
        lines.append(
            f"| {r['label']}{star} | {r['mean_util']:.3f} "
            f"| {r['mean_tflops']:.1f} | {r['total_um2'] / 1e6:.2f} "
            f"| {r['overhead_pct']:.2f} | {r['acc_mre']:.2e} "
            f"| {r['speedup_vs_tpu_v5e']:.2f}x | {r['speedup_vs_neuron_v2']:.2f}x |"
        )
    lines += [
        "",
        "(* = the paper's design point)",
        "",
        "## Instruction-level simulator cross-checks",
        "",
        "| design | seq | sim cycles | model cycles | cycles ok | MAE | ok |",
        "|---|---|---|---|---|---|---|",
    ]
    for c in report["sim_checks"]:
        lines.append(
            f"| {c['label']} | {c['seq']} | {c['cycles_sim']} "
            f"| {c['cycles_model']} | {_fmt(c['cycles_ok'])} "
            f"| {c['mae']:.2e} | {_fmt(c['mae_ok'])} |"
        )
    lines += [
        "",
        "Cycle counts from the functional simulator's §3.5 timeline must "
        "equal the closed-form model; output MAE must stay inside the "
        "paper's Table 2 envelope (3.4e-2).  Absolute errors are below the "
        "paper's RTL because the simulator keeps fp32 inter-PE partial sums.",
        "",
    ]
    return "\n".join(lines)


def write_report(
    report: dict,
    md_path: Optional[str] = None,
    json_path: Optional[str] = None,
) -> None:
    """Persist the report; strips the full record list from the JSON so the
    benchmark summary stays headline-sized (the frontier is kept)."""
    if md_path:
        with open(md_path, "w") as f:
            f.write(render_markdown(report))
    if json_path:
        payload = {k: v for k, v in report.items() if k != "records"}
        with open(json_path, "w") as f:
            json.dump(payload, f, indent=2, sort_keys=True)
            f.write("\n")
