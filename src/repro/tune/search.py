"""Search drivers: mesh-sharded grid sweep, random search, successive halving.

The analytic objectives (utilization and area) are closed-form arithmetic,
so the grid sweep evaluates them *on device*: design points are encoded as
a ``[P, 6]`` feature matrix, padded to a multiple of the device count, and
swept under ``jax.shard_map`` over a 1-D "tune" mesh axis — each device
evaluates its shard of the space, exactly the NeMo-autotuner shape scaled
down to closed forms.  The per-device shard counts come back with the
metrics so tests (and the report) can verify the sharding actually
happened.  Accuracy depends only on (N, segments, protocol seq), so it is
joined host-side from the ``objectives`` cache — one numpy evaluation per
distinct combination, not per point.

``random_search`` samples a fixed-size subspace deterministically from a
seed; ``successive_halving`` ranks on a scalarized score and re-evaluates
survivors at increasing accuracy fidelity (longer Table 2 sequences), the
classic multi-fidelity bandit over the same evaluators.
"""

from __future__ import annotations

import dataclasses
from typing import Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P

from repro.core.systolic_model import PAPER_SEQLENS, baseline_utilization

from .design import SCHEDULES, DesignPoint, exact_fit_point
from .objectives import eval_accuracy

__all__ = [
    "SweepResult",
    "tune_mesh",
    "encode_points",
    "grid_space",
    "grid_sweep",
    "random_search",
    "scalar_score",
    "successive_halving",
]

_FEATURES = ("array_n", "single_direction", "pwl_segments", "spad_kib",
             "accum_kib", "freq_ghz")
_METRICS = ("mean_util", "mean_tflops", "peak_tflops", "cycles_max_seq",
            "std_um2", "fsa_additional_um2", "array_um2", "sram_um2",
            "total_um2", "overhead_pct")


@dataclasses.dataclass
class SweepResult:
    records: list[dict]
    per_device_counts: list[int]  # design points evaluated on each device

    def __iter__(self):
        return iter(self.records)

    def __len__(self):
        return len(self.records)


def tune_mesh(num_devices: Optional[int] = None):
    """A 1-D mesh over the local devices for design-point sharding."""
    devices = jax.devices()
    if num_devices is not None:
        devices = devices[:num_devices]
    return jax.make_mesh(
        (len(devices),), ("tune",),
        devices=devices,
        axis_types=(jax.sharding.AxisType.Auto,),
    )


def encode_points(points: Sequence[DesignPoint]) -> np.ndarray:
    """[P, 6] float32 feature rows in ``_FEATURES`` order."""
    return np.asarray(
        [
            (
                p.array_n,
                1.0 if p.single_direction else 0.0,
                p.pwl_segments,
                p.spad_kib,
                p.accum_kib,
                p.freq_ghz,
            )
            for p in points
        ],
        np.float32,
    )


def _eval_features(feats: jnp.ndarray) -> jnp.ndarray:
    """[p, 6] features -> [p, len(_METRICS)] metrics, pure jnp.

    Same closed forms as ``objectives.eval_performance`` / ``eval_area``
    (§3.5 cycle counts, Table 3 component model); equality with the scalar
    host evaluators is pinned in tests/test_tune.py.
    """
    from .objectives import (
        FREQ_AREA_SLOPE,
        PAPER_AREA,
        PAPER_N,
        SPLIT_LUT_FRACTION,
        SRAM_UM2_PER_KIB,
    )

    n = feats[:, 0]
    sd = feats[:, 1]
    segs = feats[:, 2]
    spad = feats[:, 3]
    accum = feats[:, 4]
    freq = feats[:, 5]

    seqs = jnp.asarray(PAPER_SEQLENS, jnp.float32)[None, :]  # [1, S]
    nc = n[:, None]
    tiles = jnp.ceil(seqs / nc)  # Tr = Tc
    tile_cycles = (5.0 * n + 10.0 + n * sd)[:, None]
    cycles = tiles * tiles * tile_cycles + tiles * (2.0 * n + 20.0)[:, None]
    flops = 4.0 * seqs * seqs * nc
    peak_per_cycle = 2.0 * nc * nc
    util = flops / (cycles * peak_per_cycle)
    mean_util = util.mean(axis=1)
    peak_tflops = 2.0 * n * n * freq * 1e-3  # 2N^2 FLOPs/cycle at freq GHz
    cycles_max = cycles[:, -1]

    per_pe = PAPER_AREA["pes"] / (PAPER_N * PAPER_N)
    per_up = PAPER_AREA["upward"] / (PAPER_N * PAPER_N)
    per_split = PAPER_AREA["split"] / (PAPER_N * PAPER_N)
    per_cmp = PAPER_AREA["cmp"] / PAPER_N
    freq_scale = 1.0 + FREQ_AREA_SLOPE * (freq - 1.5)
    std = (per_pe * n * n + PAPER_AREA["other"]) * freq_scale
    split = per_split * n * n * (
        1.0 - SPLIT_LUT_FRACTION + SPLIT_LUT_FRACTION * segs / 8.0
    )
    upward = (1.0 - sd) * per_up * n * n
    add = (split + upward + per_cmp * n) * freq_scale
    sram = (spad + accum) * SRAM_UM2_PER_KIB

    return jnp.stack(
        [
            mean_util,
            mean_util * peak_tflops,
            peak_tflops,
            cycles_max,
            std,
            add,
            std + add,
            sram,
            std + add + sram,
            100.0 * add / (std + add),
        ],
        axis=1,
    )


def _sharded_metrics(feats: np.ndarray, mesh) -> tuple[np.ndarray, list[int]]:
    """Evaluate the feature matrix under shard_map over the "tune" axis."""
    num = feats.shape[0]
    ndev = int(mesh.shape["tune"])
    pad = (-num) % ndev
    if pad:
        # Pad with copies of the first row: harmless math, masked out below.
        feats = np.concatenate([feats, np.repeat(feats[:1], pad, axis=0)])
    valid = (np.arange(feats.shape[0]) < num).astype(np.float32)

    def body(f_local, valid_local):
        metrics = _eval_features(f_local)
        count = jnp.sum(valid_local, keepdims=True)
        return metrics, count

    with jax.set_mesh(mesh):
        metrics, counts = jax.shard_map(
            body,
            in_specs=(P("tune", None), P("tune")),
            out_specs=(P("tune", None), P("tune")),
        )(jnp.asarray(feats), jnp.asarray(valid))
    return np.asarray(metrics)[:num], [int(c) for c in np.asarray(counts)]


def grid_sweep(
    points: Sequence[DesignPoint],
    *,
    mesh=None,
    accuracy_seq: int = 2048,
    seed: int = 0,
) -> SweepResult:
    """Evaluate every point; analytic objectives sharded over ``mesh``.

    With ``mesh=None`` the same vectorized evaluator runs on one device
    (per_device_counts == [len(points)]).  Accuracy (Table 2 / Fig. 12) is
    joined from the host-side cache, one evaluation per distinct
    (N, segments); baseline speedups likewise per distinct N.
    """
    points = list(points)
    for p in points:
        p.validate()
    feats = encode_points(points)
    if mesh is not None:
        metrics, counts = _sharded_metrics(feats, mesh)
    else:
        metrics = np.asarray(_eval_features(jnp.asarray(feats)))
        counts = [len(points)]

    base_means: dict[int, dict[str, float]] = {}
    records = []
    for point, row in zip(points, metrics):
        rec = {"label": point.label(), **dataclasses.asdict(point)}
        rec.update({k: float(v) for k, v in zip(_METRICS, row)})
        n = point.array_n
        if n not in base_means:
            base_means[n] = {
                which: float(
                    np.mean([baseline_utilization(which, s, n) for s in PAPER_SEQLENS])
                )
                for which in ("tpu_v5e", "neuron_v2")
            }
        rec["speedup_vs_tpu_v5e"] = rec["mean_util"] / base_means[n]["tpu_v5e"]
        rec["speedup_vs_neuron_v2"] = rec["mean_util"] / base_means[n]["neuron_v2"]
        rec.update(eval_accuracy(point, seq=accuracy_seq, seed=seed))
        records.append(rec)
    return SweepResult(records=records, per_device_counts=counts)


# ---------------------------------------------------------------------------
# Space constructors
# ---------------------------------------------------------------------------

def grid_space(
    *,
    array_ns: Sequence[int] = (64, 128, 256),
    schedules: Sequence[str] = SCHEDULES,
    segments: Sequence[int] = (4, 8, 16),
    sram_overs: Sequence[int] = (1,),
    freqs: Sequence[float] = (1.5,),
) -> list[DesignPoint]:
    """Cartesian product of the axes, invalid points filtered out.

    SRAM is specified as an over-provisioning factor on the exact-fit
    capacity (the paper point is exact-fit at N=128), so every array size
    gets a buildable memory system; the paper's 192+64 KiB appears as
    ``array_ns=(128,), sram_overs=(1,)``.
    """
    out = []
    for n in array_ns:
        for sched in schedules:
            for k in segments:
                for over in sram_overs:
                    for f in freqs:
                        p = exact_fit_point(
                            n, schedule=sched, pwl_segments=k,
                            freq_ghz=f, sram_over=over,
                        )
                        if p.is_valid():
                            out.append(p)
    return out


def random_search(
    num_points: int,
    *,
    seed: int = 0,
    array_ns: Sequence[int] = (32, 64, 128, 256),
    schedules: Sequence[str] = SCHEDULES,
    segments: Sequence[int] = (2, 4, 8, 16, 32),
    sram_overs: Sequence[int] = (1, 2),
    freqs: Sequence[float] = (0.75, 1.0, 1.5, 2.0),
    mesh=None,
    accuracy_seq: int = 2048,
) -> SweepResult:
    """Deterministically sample ``num_points`` distinct valid points."""
    rng = np.random.default_rng(seed)
    seen: set[DesignPoint] = set()
    points: list[DesignPoint] = []
    attempts = 0
    while len(points) < num_points and attempts < num_points * 100:
        attempts += 1
        p = exact_fit_point(
            int(rng.choice(array_ns)),
            schedule=str(rng.choice(schedules)),
            pwl_segments=int(rng.choice(segments)),
            freq_ghz=float(rng.choice(freqs)),
            sram_over=int(rng.choice(sram_overs)),
        )
        if p.is_valid() and p not in seen:
            seen.add(p)
            points.append(p)
    return grid_sweep(points, mesh=mesh, accuracy_seq=accuracy_seq, seed=seed)


# ---------------------------------------------------------------------------
# Successive halving
# ---------------------------------------------------------------------------

def scalar_score(rec: dict, *, w_area: float = 0.5, w_acc: float = 5.0) -> float:
    """Fixed scalarization used only to *rank* within successive halving.

    Normalizes area by the paper total so the terms are O(1); higher is
    better.  The Pareto frontier (pareto.py) is the real multi-objective
    output — this score just decides which points graduate to the next
    fidelity rung.
    """
    from .objectives import PAPER_TARGETS

    return (
        rec["mean_util"]
        - w_area * rec["total_um2"] / PAPER_TARGETS["area_total_um2"]
        - w_acc * rec["acc_mre"]
    )


def successive_halving(
    points: Sequence[DesignPoint],
    *,
    seed: int = 0,
    eta: int = 2,
    fidelities: Sequence[int] = (256, 1024, 2048),
    mesh=None,
) -> SweepResult:
    """Multi-fidelity search: rank at short Table 2 sequences, promote the
    top 1/eta to longer ones; survivors end fully evaluated at the final
    fidelity.  Deterministic given (points, seed)."""
    result = grid_sweep(points, mesh=mesh, accuracy_seq=fidelities[0], seed=seed)
    survivors = list(zip(points, result.records))
    for fidelity in fidelities[1:]:
        keep = max(1, -(-len(survivors) // eta))
        survivors.sort(key=lambda pr: scalar_score(pr[1]), reverse=True)
        survivors = survivors[:keep]
        for point, rec in survivors:
            rec.update(eval_accuracy(point, seq=fidelity, seed=seed))
    return SweepResult(
        records=[rec for _, rec in survivors],
        per_device_counts=result.per_device_counts,
    )
