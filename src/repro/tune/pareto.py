"""Pareto-frontier extraction over (performance, area, accuracy).

The autotuner's real output is the non-dominated set, not a scalar
winner: the paper's 128x128 / 8-segment / exact-fit-SRAM point should
*sit on* this frontier (every knob it fixes is a genuine trade — more
segments buy PWL accuracy for split-LUT area, the single-direction
schedule buys area for cycles, bigger arrays buy throughput for silicon),
and the report shows where.
"""

from __future__ import annotations

from typing import Sequence

__all__ = ["OBJECTIVES", "dominates", "pareto_front", "attach_frontier"]

# (record key, direction): the default three-objective trade-off surface.
OBJECTIVES = (
    ("mean_tflops", "max"),
    ("total_um2", "min"),
    ("acc_mre", "min"),
)


def _oriented(rec: dict, objectives) -> tuple:
    """Record -> tuple where larger is always better."""
    out = []
    for key, direction in objectives:
        v = float(rec[key])
        out.append(v if direction == "max" else -v)
    return tuple(out)


def dominates(a: dict, b: dict, objectives=OBJECTIVES) -> bool:
    """True iff ``a`` is >= ``b`` on every objective and > on at least one."""
    av, bv = _oriented(a, objectives), _oriented(b, objectives)
    return all(x >= y for x, y in zip(av, bv)) and any(x > y for x, y in zip(av, bv))


def pareto_front(records: Sequence[dict], objectives=OBJECTIVES) -> list[int]:
    """Indices of the non-dominated records, in input order."""
    front = []
    for i, rec in enumerate(records):
        if not any(
            dominates(other, rec, objectives)
            for j, other in enumerate(records)
            if j != i
        ):
            front.append(i)
    return front


def attach_frontier(records: Sequence[dict], objectives=OBJECTIVES) -> list[int]:
    """Set ``rec["on_frontier"]`` on every record; return frontier indices."""
    front = pareto_front(records, objectives)
    front_set = set(front)
    for i, rec in enumerate(records):
        rec["on_frontier"] = i in front_set
    return front
