"""The FSA design space: one frozen, hashable point per candidate device.

The paper evaluates a single design point — a 128 x 128 array with the
dual-direction SystolicAttention schedule, an 8-segment PWL exp2, a
192 KiB scratchpad and a 64 KiB accumulation SRAM at 1.5 GHz (Table 1).
``DesignPoint`` names every free axis of that design so the autotuner can
sweep them:

  * ``array_n``       — systolic array dimension N (head_dim maps to N,
                        paper §3.5: Bc = N_ROWS = d);
  * ``schedule``      — "standard" (dual-direction, 5N+10 cycles/tile) or
                        "single_direction" (area-optimized §8.2 variant,
                        6N+10 cycles/tile, no upward-path registers);
  * ``pwl_segments``  — exp2 interpolation segments (paper §3.3, Fig. 12);
  * ``spad_kib``      — scratchpad SRAM capacity;
  * ``accum_kib``     — accumulation SRAM capacity;
  * ``freq_ghz``      — synthesis clock target.

Validity follows the Table 1 capacity model: the scratchpad must hold the
double-buffered Q/K/V^T fp16 working set of Listing 2 (six N x N tiles =
``12 N^2`` bytes) and the accumulation SRAM the fp32 O tile (``4 N^2``
bytes; the l vector lives in the accumulator's per-column registers).
The paper's 192 KiB / 64 KiB are the *exact* fit at N = 128 — the paper
point is minimal-SRAM by construction.
"""

from __future__ import annotations

import dataclasses

__all__ = [
    "DesignPoint",
    "SCHEDULES",
    "paper_point",
    "spad_required_bytes",
    "accum_required_bytes",
    "exact_fit_point",
]

SCHEDULES = ("standard", "single_direction")


def spad_required_bytes(array_n: int) -> int:
    """Double-buffered Q/K/V^T fp16 tiles (Listing 2): 6 tiles of 2N^2 B."""
    return 12 * array_n * array_n


def accum_required_bytes(array_n: int) -> int:
    """One fp32 O tile ([d, Br] = N x N); l is held in accumulator registers."""
    return 4 * array_n * array_n


def _is_pow2(x: int) -> bool:
    return x >= 1 and (x & (x - 1)) == 0


@dataclasses.dataclass(frozen=True)
class DesignPoint:
    """One hashable FSA configuration; the default is the paper's design."""

    array_n: int = 128
    schedule: str = "standard"
    pwl_segments: int = 8
    spad_kib: int = 192
    accum_kib: int = 64
    freq_ghz: float = 1.5

    # -- derived ------------------------------------------------------------

    @property
    def single_direction(self) -> bool:
        return self.schedule == "single_direction"

    @property
    def spad_bytes(self) -> int:
        return self.spad_kib * 1024

    @property
    def accum_bytes(self) -> int:
        return self.accum_kib * 1024

    @property
    def peak_flops_per_cycle(self) -> float:
        return 2.0 * self.array_n * self.array_n

    def label(self) -> str:
        sched = "1dir" if self.single_direction else "2dir"
        return (
            f"N{self.array_n}/{sched}/K{self.pwl_segments}"
            f"/S{self.spad_kib}+{self.accum_kib}KiB/{self.freq_ghz:g}GHz"
        )

    # -- validity (Table 1 capacity model) ----------------------------------

    def validate(self) -> None:
        """Raise ValueError when the point is not a buildable FSA device."""
        if not _is_pow2(self.array_n) or self.array_n < 8:
            raise ValueError(
                f"array_n must be a power of two >= 8 (lane alignment), got "
                f"{self.array_n}"
            )
        if self.schedule not in SCHEDULES:
            raise ValueError(f"schedule must be one of {SCHEDULES}, got {self.schedule!r}")
        if not _is_pow2(self.pwl_segments) or not 2 <= self.pwl_segments <= 64:
            raise ValueError(
                "pwl_segments must be a power of two in [2, 64] (the segment "
                f"index is encoded in intercept exponent MSBs, §3.3), got "
                f"{self.pwl_segments}"
            )
        need_spad = spad_required_bytes(self.array_n)
        if self.spad_bytes < need_spad:
            raise ValueError(
                f"scratchpad {self.spad_kib} KiB cannot hold the double-"
                f"buffered Q/K/V^T working set of an N={self.array_n} array "
                f"({need_spad} bytes, Table 1)"
            )
        need_accum = accum_required_bytes(self.array_n)
        if self.accum_bytes < need_accum:
            raise ValueError(
                f"accumulation SRAM {self.accum_kib} KiB cannot hold the fp32 "
                f"O tile of an N={self.array_n} array ({need_accum} bytes, "
                f"Table 1)"
            )
        if not 0.25 <= self.freq_ghz <= 4.0:
            raise ValueError(f"freq_ghz outside the modelled range: {self.freq_ghz}")

    def is_valid(self) -> bool:
        try:
            self.validate()
        except ValueError:
            return False
        return True


def paper_point() -> DesignPoint:
    """The paper's published design (Table 1): all defaults."""
    return DesignPoint()


def exact_fit_point(
    array_n: int,
    *,
    schedule: str = "standard",
    pwl_segments: int = 8,
    freq_ghz: float = 1.5,
    sram_over: int = 1,
) -> DesignPoint:
    """A point with minimal (or ``sram_over``x) SRAM for its array size."""
    spad = spad_required_bytes(array_n) * sram_over
    accum = accum_required_bytes(array_n) * sram_over
    return DesignPoint(
        array_n=array_n,
        schedule=schedule,
        pwl_segments=pwl_segments,
        spad_kib=-(-spad // 1024),
        accum_kib=-(-accum // 1024),
        freq_ghz=freq_ghz,
    )
