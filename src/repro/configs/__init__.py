from .base import SHAPES, ModelConfig, MoEConfig, SSMConfig, ShapeConfig, XLSTMConfig  # noqa: F401
from .registry import ARCH_IDS, get_config, get_smoke_config, runnable_cells, skipped_cells  # noqa: F401
