"""xlstm-125m [ssm] — 12L d_model=768 4H d_ff=0 vocab=50304.
Alternating sLSTM + mLSTM blocks; attention-free (the paper's technique is
inapplicable — DESIGN.md §5).  [arXiv:2405.04517]
Constant-size recurrent state -> runs the long_500k cell."""
import dataclasses
from .base import ModelConfig, XLSTMConfig

CONFIG = ModelConfig(
    name="xlstm-125m",
    family="ssm",
    num_layers=12,          # 6 scanned (mLSTM, sLSTM) pairs
    d_model=768,
    num_heads=4,
    num_kv_heads=4,
    head_dim=192,
    d_ff=0,
    vocab_size=50304,
    mlp_type="gelu",
    tie_embeddings=True,
    xlstm=XLSTMConfig(),
)

SMOKE_CONFIG = dataclasses.replace(
    CONFIG,
    num_layers=4, d_model=64, num_heads=4, num_kv_heads=4, head_dim=16,
    vocab_size=256, dtype="float32", remat=False,
)
