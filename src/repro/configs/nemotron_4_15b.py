"""nemotron-4-15b [dense] — 32L d_model=6144 48H (GQA kv=8) d_ff=24576
vocab=256000.  Squared-ReLU MLP (no gate), GQA.  [arXiv:2402.16819]"""
import dataclasses
from .base import ModelConfig

CONFIG = ModelConfig(
    name="nemotron-4-15b",
    family="dense",
    num_layers=32,
    d_model=6144,
    num_heads=48,
    num_kv_heads=8,
    head_dim=128,
    d_ff=24576,
    vocab_size=256000,
    mlp_type="squared_relu",
    norm_type="layernorm",
    rope_theta=10_000.0,
)

SMOKE_CONFIG = dataclasses.replace(
    CONFIG,
    num_layers=2, d_model=64, num_heads=4, num_kv_heads=2, head_dim=16,
    d_ff=192, vocab_size=512, dtype="float32", remat=False,
)
