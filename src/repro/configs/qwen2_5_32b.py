"""qwen2.5-32b [dense] — 64L d_model=5120 40H (GQA kv=8) d_ff=27648
vocab=152064.  GQA with QKV bias.  [hf:Qwen/Qwen2.5-0.5B; hf]"""
import dataclasses
from .base import ModelConfig

CONFIG = ModelConfig(
    name="qwen2.5-32b",
    family="dense",
    num_layers=64,
    d_model=5120,
    num_heads=40,
    num_kv_heads=8,
    head_dim=128,
    d_ff=27648,
    vocab_size=152064,
    mlp_type="swiglu",
    qkv_bias=True,
    rope_theta=1_000_000.0,
)

SMOKE_CONFIG = dataclasses.replace(
    CONFIG,
    num_layers=2, d_model=64, num_heads=4, num_kv_heads=2, head_dim=16,
    d_ff=128, vocab_size=256, dtype="float32", remat=False,
)
