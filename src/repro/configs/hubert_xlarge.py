"""hubert-xlarge [audio] — 48L d_model=1280 16H d_ff=5120 vocab=504.
Encoder-only (bidirectional), same backbone as wav2vec2.  [arXiv:2106.07447]
The conv waveform frontend is a STUB: input_specs() provides precomputed
frame embeddings [B, S, d]; the head predicts one of 504 cluster labels per
frame.  Non-causal attention is exactly the paper's evaluated setting."""
import dataclasses
from .base import ModelConfig

CONFIG = ModelConfig(
    name="hubert-xlarge",
    family="encoder",
    num_layers=48,
    d_model=1280,
    num_heads=16,
    num_kv_heads=16,
    head_dim=80,
    d_ff=5120,
    vocab_size=504,
    mlp_type="gelu",
    norm_type="layernorm",
    causal=False,
    embedding_inputs=True,
    rope_theta=10_000.0,
)

SMOKE_CONFIG = dataclasses.replace(
    CONFIG,
    num_layers=2, d_model=64, num_heads=4, num_kv_heads=4, head_dim=16,
    d_ff=128, vocab_size=64, dtype="float32", remat=False,
)
