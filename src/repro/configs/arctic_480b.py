"""arctic-480b [moe] — 35L d_model=7168 56H (GQA kv=8) d_ff=4864 vocab=32000,
MoE 128 experts top-2 + dense residual.  [hf:Snowflake/snowflake-arctic-base; hf]
Arctic is a dense-MoE hybrid: a small dense FFN runs in residual parallel
with the routed experts."""
import dataclasses
from .base import ModelConfig, MoEConfig

CONFIG = ModelConfig(
    name="arctic-480b",
    family="moe",
    num_layers=35,
    d_model=7168,
    num_heads=56,
    num_kv_heads=8,
    head_dim=128,
    d_ff=4864,
    vocab_size=32000,
    mlp_type="swiglu",
    rope_theta=10_000.0,
    moe=MoEConfig(num_experts=128, top_k=2, d_ff_expert=4864, dense_residual=True),
)

SMOKE_CONFIG = dataclasses.replace(
    CONFIG,
    num_layers=2, d_model=64, num_heads=4, num_kv_heads=2, head_dim=16,
    d_ff=96, vocab_size=256,
    moe=MoEConfig(num_experts=8, top_k=2, d_ff_expert=96, dense_residual=True),
    dtype="float32", remat=False,
)
