"""qwen3-moe-235b-a22b [moe] — 94L d_model=4096 64H (GQA kv=4) d_ff=1536
vocab=151936, MoE 128 experts top-8.  [hf:Qwen/Qwen3-30B-A3B; hf]
Qwen3 uses QK-Norm and no QKV bias."""
import dataclasses
from .base import ModelConfig, MoEConfig

CONFIG = ModelConfig(
    name="qwen3-moe-235b-a22b",
    family="moe",
    num_layers=94,
    d_model=4096,
    num_heads=64,
    num_kv_heads=4,
    head_dim=128,
    d_ff=1536,
    vocab_size=151936,
    mlp_type="swiglu",
    qk_norm=True,
    rope_theta=1_000_000.0,
    moe=MoEConfig(num_experts=128, top_k=8, d_ff_expert=1536),
)

SMOKE_CONFIG = dataclasses.replace(
    CONFIG,
    num_layers=2, d_model=64, num_heads=4, num_kv_heads=2, head_dim=16,
    d_ff=96, vocab_size=256,
    moe=MoEConfig(num_experts=8, top_k=2, d_ff_expert=96),
    dtype="float32", remat=False,
)
