"""yi-9b [dense] — 48L d_model=4096 32H (GQA kv=4) d_ff=11008 vocab=64000.
Llama-architecture GQA.  [arXiv:2403.04652; hf]"""
import dataclasses
from .base import ModelConfig

CONFIG = ModelConfig(
    name="yi-9b",
    family="dense",
    num_layers=48,
    d_model=4096,
    num_heads=32,
    num_kv_heads=4,
    head_dim=128,
    d_ff=11008,
    vocab_size=64000,
    mlp_type="swiglu",
    rope_theta=10_000.0,
)

SMOKE_CONFIG = dataclasses.replace(
    CONFIG,
    num_layers=2, d_model=64, num_heads=4, num_kv_heads=2, head_dim=16,
    d_ff=128, vocab_size=256, dtype="float32", remat=False,
)
