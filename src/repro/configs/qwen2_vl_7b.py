"""qwen2-vl-7b [vlm] — 28L d_model=3584 28H (GQA kv=4) d_ff=18944
vocab=152064.  M-RoPE (t/h/w sections), dynamic resolution.  [arXiv:2409.12191]
The vision frontend is a STUB: train/prefill consume precomputed patch
embeddings + 3D positions from input_specs(); decode embeds generated tokens.
M-RoPE sections (16, 24, 24) partition the 64 head_dim/2 slots."""
import dataclasses
from .base import ModelConfig

CONFIG = ModelConfig(
    name="qwen2-vl-7b",
    family="vlm",
    num_layers=28,
    d_model=3584,
    num_heads=28,
    num_kv_heads=4,
    head_dim=128,
    d_ff=18944,
    vocab_size=152064,
    mlp_type="swiglu",
    qkv_bias=True,
    rope_theta=1_000_000.0,
    mrope_sections=(16, 24, 24),
    embedding_inputs=True,
)

SMOKE_CONFIG = dataclasses.replace(
    CONFIG,
    num_layers=2, d_model=64, num_heads=4, num_kv_heads=2, head_dim=16,
    d_ff=128, vocab_size=256, mrope_sections=(4, 2, 2),
    dtype="float32", remat=False,
)
