"""olmo-1b [dense] — 16L d_model=2048 16H d_ff=8192 vocab=50304.
Non-parametric LayerNorm (no learnable scale/bias); SwiGLU; tied embeddings.
[arXiv:2402.00838; hf]"""
import dataclasses
from .base import ModelConfig

CONFIG = ModelConfig(
    name="olmo-1b",
    family="dense",
    num_layers=16,
    d_model=2048,
    num_heads=16,
    num_kv_heads=16,
    head_dim=128,
    d_ff=8192,
    vocab_size=50304,
    mlp_type="swiglu",
    norm_type="non_parametric",
    tie_embeddings=True,
    rope_theta=10_000.0,
)

SMOKE_CONFIG = dataclasses.replace(
    CONFIG,
    num_layers=2, d_model=64, num_heads=4, num_kv_heads=4, head_dim=16,
    d_ff=128, vocab_size=256, dtype="float32", remat=False,
)
