"""Architecture registry: full configs + reduced smoke configs + cell rules."""

from __future__ import annotations

import dataclasses
import importlib
from typing import Union

from repro.quant.config import QuantConfig, parse_quant

from .base import SHAPES, ModelConfig, ShapeConfig

ARCH_IDS = (
    "qwen3-moe-235b-a22b",
    "arctic-480b",
    "hubert-xlarge",
    "olmo-1b",
    "nemotron-4-15b",
    "qwen2.5-32b",
    "yi-9b",
    "qwen2-vl-7b",
    "zamba2-1.2b",
    "xlstm-125m",
)

_MODULES = {
    "qwen3-moe-235b-a22b": "qwen3_moe_235b",
    "arctic-480b": "arctic_480b",
    "hubert-xlarge": "hubert_xlarge",
    "olmo-1b": "olmo_1b",
    "nemotron-4-15b": "nemotron_4_15b",
    "qwen2.5-32b": "qwen2_5_32b",
    "yi-9b": "yi_9b",
    "qwen2-vl-7b": "qwen2_vl_7b",
    "zamba2-1.2b": "zamba2_1_2b",
    "xlstm-125m": "xlstm_125m",
}


def _with_quant(
    cfg: ModelConfig, quant: Union[QuantConfig, str, None]
) -> ModelConfig:
    """Overlay a quantization policy (a QuantConfig or a --quant flag)."""
    if quant is None:
        return cfg
    if isinstance(quant, str):
        quant = parse_quant(quant)
    return dataclasses.replace(cfg, quant=quant)


def get_config(
    arch: str, quant: Union[QuantConfig, str, None] = None
) -> ModelConfig:
    mod = importlib.import_module(f"repro.configs.{_MODULES[arch]}")
    return _with_quant(mod.CONFIG, quant)


def get_smoke_config(
    arch: str, quant: Union[QuantConfig, str, None] = None
) -> ModelConfig:
    mod = importlib.import_module(f"repro.configs.{_MODULES[arch]}")
    return _with_quant(mod.SMOKE_CONFIG, quant)


def runnable_cells() -> list[tuple[str, str]]:
    """All (arch, shape) dry-run cells, applying the skip rules:
    - encoder-only archs (hubert) have no decode step -> skip decode shapes;
    - long_500k needs sub-quadratic attention -> only hybrid/ssm archs.
    """
    cells = []
    for arch in ARCH_IDS:
        cfg = get_config(arch)
        for shape_name, shape in SHAPES.items():
            if shape.kind == "decode" and cfg.family == "encoder":
                continue  # no decode step exists
            if shape_name == "long_500k" and cfg.family not in ("hybrid", "ssm"):
                continue  # O(S^2) full attention; skip per brief
            cells.append((arch, shape_name))
    return cells


def skipped_cells() -> list[tuple[str, str, str]]:
    out = []
    for arch in ARCH_IDS:
        cfg = get_config(arch)
        for shape_name, shape in SHAPES.items():
            if shape.kind == "decode" and cfg.family == "encoder":
                out.append((arch, shape_name, "encoder-only: no decode step"))
            elif shape_name == "long_500k" and cfg.family not in ("hybrid", "ssm"):
                out.append((arch, shape_name, "pure full attention: O(S^2) at 524k"))
    return out
