"""Model / run configuration dataclasses and the input-shape registry."""

from __future__ import annotations

import dataclasses
from typing import Optional

import jax.numpy as jnp

from repro.quant.config import QuantConfig


@dataclasses.dataclass(frozen=True)
class MoEConfig:
    num_experts: int
    top_k: int
    d_ff_expert: int
    dense_residual: bool = False  # arctic: dense FFN in parallel with MoE
    capacity_factor: float = 1.25
    router_dtype: str = "float32"


@dataclasses.dataclass(frozen=True)
class SSMConfig:
    state_dim: int = 64
    head_dim: int = 64
    conv_width: int = 4
    expand: int = 2
    chunk_size: int = 64


@dataclasses.dataclass(frozen=True)
class XLSTMConfig:
    # Ratio of mLSTM to sLSTM blocks inside each scanned super-block.
    mlstm_per_block: int = 1
    slstm_per_block: int = 1
    chunk_size: int = 64
    conv_width: int = 4


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str  # dense | moe | encoder | vlm | hybrid | ssm
    num_layers: int
    d_model: int
    num_heads: int
    num_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: Optional[int] = None  # default d_model // num_heads
    mlp_type: str = "swiglu"  # swiglu | gelu | squared_relu
    norm_type: str = "rmsnorm"  # rmsnorm | layernorm | non_parametric
    qkv_bias: bool = False
    qk_norm: bool = False
    causal: bool = True
    tie_embeddings: bool = False
    rope_theta: float = 10000.0
    mrope_sections: Optional[tuple[int, int, int]] = None  # qwen2-vl M-RoPE
    moe: Optional[MoEConfig] = None
    ssm: Optional[SSMConfig] = None
    xlstm: Optional[XLSTMConfig] = None
    attn_every: int = 0  # zamba2: shared attention block every N ssm layers
    # Execution knobs
    parallelism: str = "tp"  # tp (Megatron TP+DP+SP) | dp_only (pure DP+ZeRO)
    attention_impl: str = "systolic"  # systolic | pallas | naive
    exp2_impl: str = "exact"  # exact | pwl (paper-faithful numerics)
    attn_block_q: int = 128
    attn_block_k: int = 128
    dtype: str = "bfloat16"
    remat: bool = True
    # int8 quantization policy (repro.quant): which layer classes run
    # integer-domain matmuls and whether the KV cache stores int8.  None
    # means fully full-precision (the default everywhere).
    quant: Optional[QuantConfig] = None
    # Dry-run knobs: XLA's cost_analysis counts while-loop bodies once, so
    # the roofline harness unrolls the attention KV scans fully
    # (attn_unroll) and compiles the layer scan at unroll=1 and unroll=2 to
    # extrapolate exact totals (see launch/dryrun.py).
    scan_unroll: int = 1
    attn_unroll: bool = False
    # Frontend stubs ([audio]/[vlm]): the model consumes precomputed
    # frame/patch embeddings instead of token ids.
    embedding_inputs: bool = False
    logit_softcap: float = 0.0

    @property
    def num_scan_steps(self) -> int:
        """Trip count of the layer scan (for cost extrapolation)."""
        if self.family == "ssm":
            return self.num_layers // 2  # (mLSTM, sLSTM) pairs
        return self.num_layers

    @property
    def resolved_head_dim(self) -> int:
        return self.head_dim if self.head_dim else self.d_model // self.num_heads

    @property
    def activation_dtype(self):
        return jnp.dtype(self.dtype)

    def param_count(self) -> int:
        """Analytic parameter count (used for 6·N·D model FLOPs)."""
        d, v, L = self.d_model, self.vocab_size, self.num_layers
        hd = self.resolved_head_dim
        emb = v * d * (1 if self.tie_embeddings else 2)
        if self.family == "ssm":  # xLSTM
            d_in = d * (self.xlstm.mlstm_per_block and 2 or 2)
            per = 2 * d * 2 * d * 2  # rough in/out projections of both block types
            return emb + L * per
        attn = d * self.num_heads * hd + 2 * d * self.num_kv_heads * hd + self.num_heads * hd * d
        if self.mlp_type == "swiglu":
            mlp = 3 * d * self.d_ff
        else:
            mlp = 2 * d * self.d_ff
        per_layer = attn + mlp
        if self.moe is not None:
            expert = (3 if self.mlp_type == "swiglu" else 2) * d * self.moe.d_ff_expert
            per_layer = attn + self.moe.num_experts * expert + d * self.moe.num_experts
            if self.moe.dense_residual:
                per_layer += 3 * d * self.d_ff
        if self.family == "hybrid":
            # Mamba2 layers + one shared attention block.
            d_inner = self.ssm.expand * d
            nheads = d_inner // self.ssm.head_dim
            mamba = (
                d * (2 * d_inner + 2 * self.ssm.state_dim + nheads)  # in_proj
                + d_inner * d  # out_proj
                + self.ssm.conv_width * (d_inner + 2 * self.ssm.state_dim)
            )
            shared_attn = attn + 3 * d * self.d_ff
            return emb + L * mamba + shared_attn
        return emb + L * per_layer

    def active_param_count(self) -> int:
        """Active params per token (MoE: only top-k experts count)."""
        if self.moe is None:
            return self.param_count()
        d = self.d_model
        expert = (3 if self.mlp_type == "swiglu" else 2) * d * self.moe.d_ff_expert
        inactive = (self.moe.num_experts - self.moe.top_k) * expert
        return self.param_count() - self.num_layers * inactive


@dataclasses.dataclass(frozen=True)
class ShapeConfig:
    name: str
    seq_len: int
    global_batch: int
    kind: str  # train | prefill | decode


# The assigned LM-family shape set (applies to every architecture).
SHAPES = {
    "train_4k": ShapeConfig("train_4k", 4_096, 256, "train"),
    "prefill_32k": ShapeConfig("prefill_32k", 32_768, 32, "prefill"),
    "decode_32k": ShapeConfig("decode_32k", 32_768, 128, "decode"),
    "long_500k": ShapeConfig("long_500k", 524_288, 1, "decode"),
}
