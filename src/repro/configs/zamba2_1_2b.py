"""zamba2-1.2b [hybrid] — 38L d_model=2048 32H (kv=32) d_ff=8192 vocab=32000,
ssm_state=64.  Mamba2 backbone + ONE shared attention(+MLP) block applied
every 6 Mamba layers (weights shared across applications, per-application KV
caches).  [arXiv:2411.15242; hf]
Sub-quadratic end-to-end -> runs the long_500k cell."""
import dataclasses
from .base import ModelConfig, SSMConfig

CONFIG = ModelConfig(
    name="zamba2-1.2b",
    family="hybrid",
    num_layers=38,
    d_model=2048,
    num_heads=32,
    num_kv_heads=32,
    head_dim=64,
    d_ff=8192,
    vocab_size=32000,
    mlp_type="swiglu",
    rope_theta=10_000.0,
    ssm=SSMConfig(state_dim=64, head_dim=64, conv_width=4, expand=2, chunk_size=128),
    attn_every=6,
)

SMOKE_CONFIG = dataclasses.replace(
    CONFIG,
    num_layers=4, d_model=64, num_heads=4, num_kv_heads=4, head_dim=16,
    d_ff=128, vocab_size=256,
    ssm=SSMConfig(state_dim=8, head_dim=16, conv_width=4, expand=2, chunk_size=16),
    attn_every=2, dtype="float32", remat=False,
)
