"""Deterministic, shardable data pipeline.

Production shape: every host generates/reads only its shard of the global
batch (``host_batch = global_batch / num_hosts``), keyed by
(seed, step, host_id) so restarts are exactly reproducible and elastic
rescaling re-partitions cleanly (the key stream is per *global example
index*, not per host).

Sources:
  * SyntheticLM — unigram-biased random token stream with a deterministic
    label shift (the default; hermetic, infinite);
  * SyntheticEmbeds — frame/patch embedding stand-ins for the [audio]/[vlm]
    frontend-stub architectures;
  * TokenFileSource — memory-mapped pre-tokenized .npy corpus for real runs.

A background prefetch thread keeps ``prefetch`` batches ready so host-side
generation overlaps device compute.
"""

from __future__ import annotations

import dataclasses
import queue
import threading
from typing import Iterator, Optional

import numpy as np

from repro.configs.base import ModelConfig, ShapeConfig


@dataclasses.dataclass
class DataConfig:
    seed: int = 0
    num_hosts: int = 1
    host_id: int = 0
    prefetch: int = 2


def _example_rng(seed: int, step: int, example_idx: int) -> np.random.Generator:
    # Counter-based keying -> identical stream regardless of host layout.
    return np.random.default_rng(
        np.random.SeedSequence([seed, step, example_idx])
    )


class SyntheticLM:
    """Zipf-ish token stream; labels are tokens shifted by one."""

    def __init__(self, cfg: ModelConfig, shape: ShapeConfig, data: DataConfig):
        self.cfg, self.shape, self.data = cfg, shape, data
        assert shape.global_batch % data.num_hosts == 0
        self.host_batch = shape.global_batch // data.num_hosts

    def batch(self, step: int) -> dict[str, np.ndarray]:
        s, v = self.shape.seq_len, self.cfg.vocab_size
        toks = np.empty((self.host_batch, s + 1), np.int32)
        base = self.data.host_id * self.host_batch
        for i in range(self.host_batch):
            rng = _example_rng(self.data.seed, step, base + i)
            # Zipf-biased unigram draw, clipped to vocab.
            z = rng.zipf(1.3, size=s + 1)
            toks[i] = np.minimum(z - 1, v - 1)
        return {"tokens": toks[:, :-1], "labels": toks[:, 1:].copy()}


class SyntheticEmbeds:
    """Precomputed frame/patch embeddings for frontend-stub archs."""

    def __init__(self, cfg: ModelConfig, shape: ShapeConfig, data: DataConfig):
        self.cfg, self.shape, self.data = cfg, shape, data
        self.host_batch = shape.global_batch // data.num_hosts

    def batch(self, step: int) -> dict[str, np.ndarray]:
        s, d, v = self.shape.seq_len, self.cfg.d_model, self.cfg.vocab_size
        embeds = np.empty((self.host_batch, s, d), np.float32)
        labels = np.empty((self.host_batch, s), np.int32)
        base = self.data.host_id * self.host_batch
        for i in range(self.host_batch):
            rng = _example_rng(self.data.seed, step, base + i)
            embeds[i] = rng.standard_normal((s, d)).astype(np.float32)
            labels[i] = rng.integers(0, v, size=s)
        out = {"embeds": embeds, "labels": labels}
        if self.cfg.mrope_sections is not None:
            pos = np.broadcast_to(
                np.arange(s, dtype=np.int32)[None, :, None],
                (self.host_batch, s, 3),
            ).copy()
            out["positions"] = pos
        return out


class TokenFileSource:
    """Pre-tokenized flat .npy corpus, strided deterministic sampling."""

    def __init__(self, path: str, cfg: ModelConfig, shape: ShapeConfig, data: DataConfig):
        self.tokens = np.load(path, mmap_mode="r")
        self.cfg, self.shape, self.data = cfg, shape, data
        self.host_batch = shape.global_batch // data.num_hosts
        self.num_windows = (len(self.tokens) - 1) // shape.seq_len

    def batch(self, step: int) -> dict[str, np.ndarray]:
        s = self.shape.seq_len
        base = self.data.host_id * self.host_batch
        idx = (step * self.shape.global_batch + base + np.arange(self.host_batch)) % self.num_windows
        toks = np.stack([self.tokens[i * s : i * s + s + 1] for i in idx]).astype(np.int32)
        return {"tokens": toks[:, :-1], "labels": toks[:, 1:].copy()}


def make_source(cfg: ModelConfig, shape: ShapeConfig, data: DataConfig,
                token_file: Optional[str] = None):
    if token_file:
        return TokenFileSource(token_file, cfg, shape, data)
    if cfg.embedding_inputs:
        return SyntheticEmbeds(cfg, shape, data)
    return SyntheticLM(cfg, shape, data)


class PrefetchIterator:
    """Background-thread prefetch of ``source.batch(step)`` for step=start.."""

    def __init__(self, source, start_step: int = 0, prefetch: int = 2):
        self.source = source
        self.q: queue.Queue = queue.Queue(maxsize=prefetch)
        self._stop = threading.Event()
        self._step = start_step
        self._thread = threading.Thread(target=self._worker, daemon=True)
        self._thread.start()

    def _worker(self):
        step = self._step
        while not self._stop.is_set():
            try:
                self.q.put(self.source.batch(step), timeout=0.5)
                step += 1
            except queue.Full:
                continue

    def __iter__(self) -> Iterator[dict]:
        return self

    def __next__(self) -> dict:
        return self.q.get()

    def close(self):
        self._stop.set()
        self._thread.join(timeout=2)
