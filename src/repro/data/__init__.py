from .pipeline import DataConfig, PrefetchIterator, SyntheticEmbeds, SyntheticLM, TokenFileSource, make_source  # noqa: F401
