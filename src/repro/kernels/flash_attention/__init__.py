from .kernel import flash_attention_fwd  # noqa: F401
from .kernel_bwd import flash_attention_bwd  # noqa: F401
from .ops import flash_attention  # noqa: F401
from .ref import attention_reference  # noqa: F401
