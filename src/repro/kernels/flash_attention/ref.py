"""Pure-jnp oracle for the flash-attention Pallas kernels.

The contract for every kernel in this package: ``ops.flash_attention(...)``
must match ``ref.attention_reference(...)`` to fp32 tolerance (or to the
paper's Table-2 error envelope when ``exp2_impl='pwl'``).
"""

from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np


def attention_reference(
    q: jax.Array,  # [B, Sq, H, d]
    k: jax.Array,  # [B, Sk, Hkv, d]
    v: jax.Array,  # [B, Sk, Hkv, d]
    *,
    causal: bool = False,
    scale: Optional[float] = None,
    q_offset: int = 0,
) -> jax.Array:
    """Materialized-softmax attention in fp32; GQA by kv-head repetition."""
    b, sq, h, d = q.shape
    hkv = k.shape[2]
    rep = h // hkv
    if scale is None:
        scale = 1.0 / float(np.sqrt(d))
    kr = jnp.repeat(k, rep, axis=2) if rep > 1 else k
    vr = jnp.repeat(v, rep, axis=2) if rep > 1 else v
    s = jnp.einsum("bqhd,bkhd->bhqk", q.astype(jnp.float32), kr.astype(jnp.float32))
    s = s * scale
    if causal:
        rows = q_offset + jnp.arange(sq)[:, None]
        cols = jnp.arange(k.shape[1])[None, :]
        s = jnp.where(rows >= cols, s, -jnp.inf)
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bhqk,bkhd->bqhd", p, vr.astype(jnp.float32))
    return o.astype(q.dtype)
