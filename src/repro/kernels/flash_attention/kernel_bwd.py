"""FlashAttention-2 backward pass as Pallas TPU kernels.

Same VMEM-blocked structure as the forward (DESIGN.md §2): the forward
saves the base-2 log-sum-exp row statistics L (so P = exp2(c·S − L) is
recomputed per tile, never stored), and the backward runs two grid-clean
kernels:

  * dq kernel — grid (B·H, i, j), KV innermost, dq accumulates in VMEM
    scratch (mirror of the forward);
  * dkv kernel — grid (B·H, j, i), Q innermost, dk/dv accumulate in VMEM
    scratch; GQA partials over the rep q-heads are summed outside (one
    cheap reshape-sum) so no grid step ever writes another step's block.

All matmul work uses fp32 accumulation; masks are additive [Bq, Bk]
biases as in the forward.
"""

from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.core.pwl_exp2 import LOG2_E

NEG_INF = -1e30


def _mask_bias(i, j, block_q, block_k, causal, q_offset, seq_k, pad_k):
    cols = j * block_k + jax.lax.broadcasted_iota(jnp.int32, (block_q, block_k), 1)
    bias = jnp.zeros((block_q, block_k), jnp.float32)
    if pad_k:
        bias = bias + jnp.where(cols < seq_k, 0.0, NEG_INF)
    if causal:
        rows = (
            i * block_q + q_offset
            + jax.lax.broadcasted_iota(jnp.int32, (block_q, block_k), 0)
        )
        bias = bias + jnp.where(rows >= cols, 0.0, NEG_INF)
    return bias


def _dq_kernel(q_ref, k_ref, v_ref, do_ref, lse_ref, delta_ref, dq_ref, acc,
               *, num_k_blocks, block_q, block_k, causal, sm_scale, q_offset,
               seq_k, pad_k):
    j = pl.program_id(2)
    i = pl.program_id(1)

    @pl.when(j == 0)
    def _():
        acc[...] = jnp.zeros_like(acc)

    c = sm_scale * LOG2_E
    q = q_ref[0].astype(jnp.float32)
    k = k_ref[0].astype(jnp.float32)
    v = v_ref[0].astype(jnp.float32)
    do = do_ref[0].astype(jnp.float32)
    lse = lse_ref[0]      # [bq]
    delta = delta_ref[0]  # [bq] = rowsum(dO * O)

    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32)
    s = s + _mask_bias(i, j, block_q, block_k, causal, q_offset, seq_k, pad_k)
    p = jnp.exp2(c * s - lse[:, None])  # recompute (never stored)
    dp = jax.lax.dot_general(do, v, (((1,), (1,)), ((), ())),
                             preferred_element_type=jnp.float32)
    ds = p * (dp - delta[:, None]) * sm_scale
    acc[...] += jax.lax.dot_general(ds, k, (((1,), (0,)), ((), ())),
                                    preferred_element_type=jnp.float32)

    @pl.when(j == num_k_blocks - 1)
    def _():
        dq_ref[0, :, :] = acc[...].astype(dq_ref.dtype)


def _dkv_kernel(q_ref, k_ref, v_ref, do_ref, lse_ref, delta_ref,
                dk_ref, dv_ref, dk_acc, dv_acc,
                *, num_q_blocks, block_q, block_k, causal, sm_scale, q_offset,
                seq_k, pad_k):
    i = pl.program_id(2)  # q innermost
    j = pl.program_id(1)

    @pl.when(i == 0)
    def _():
        dk_acc[...] = jnp.zeros_like(dk_acc)
        dv_acc[...] = jnp.zeros_like(dv_acc)

    c = sm_scale * LOG2_E
    q = q_ref[0].astype(jnp.float32)
    k = k_ref[0].astype(jnp.float32)
    v = v_ref[0].astype(jnp.float32)
    do = do_ref[0].astype(jnp.float32)
    lse = lse_ref[0]
    delta = delta_ref[0]

    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32)
    s = s + _mask_bias(i, j, block_q, block_k, causal, q_offset, seq_k, pad_k)
    p = jnp.exp2(c * s - lse[:, None])  # [bq, bk]
    dv_acc[...] += jax.lax.dot_general(p, do, (((0,), (0,)), ((), ())),
                                       preferred_element_type=jnp.float32)
    dp = jax.lax.dot_general(do, v, (((1,), (1,)), ((), ())),
                             preferred_element_type=jnp.float32)
    ds = p * (dp - delta[:, None]) * sm_scale  # [bq, bk]
    dk_acc[...] += jax.lax.dot_general(ds, q, (((0,), (0,)), ((), ())),
                                       preferred_element_type=jnp.float32)

    @pl.when(i == num_q_blocks - 1)
    def _():
        dk_ref[0, :, :] = dk_acc[...].astype(dk_ref.dtype)
        dv_ref[0, :, :] = dv_acc[...].astype(dv_ref.dtype)


def flash_attention_bwd(
    q: jax.Array,   # [B, Sq, H, d]
    k: jax.Array,   # [B, Sk, Hkv, d]
    v: jax.Array,   # [B, Sk, Hkv, d]
    out: jax.Array,  # [B, Sq, H, d] forward output
    lse: jax.Array,  # [B*H, padded_Sq] base-2 LSE from the forward
    do: jax.Array,  # [B, Sq, H, d]
    *,
    causal: bool = False,
    scale: Optional[float] = None,
    q_offset: int = 0,
    block_q: int = 128,
    block_k: int = 128,
    interpret: bool = False,
):
    batch, sq, h, d = q.shape
    _, sk, hkv, _ = k.shape
    rep = h // hkv
    if scale is None:
        scale = 1.0 / float(np.sqrt(d))
    block_q = min(block_q, sq)
    block_k = min(block_k, sk)
    num_q = -(-sq // block_q)
    num_k = -(-sk // block_k)
    pad_q = num_q * block_q - sq
    pad_k = num_k * block_k - sk

    def headmajor(x, heads):
        x = x.transpose(0, 2, 1, 3).reshape(batch * heads, x.shape[1], d)
        return x

    qh, doh, oh = headmajor(q, h), headmajor(do, h), headmajor(out, h)
    kh, vh = headmajor(k, hkv), headmajor(v, hkv)
    if pad_q:
        qh = jnp.pad(qh, ((0, 0), (0, pad_q), (0, 0)))
        doh = jnp.pad(doh, ((0, 0), (0, pad_q), (0, 0)))
        oh = jnp.pad(oh, ((0, 0), (0, pad_q), (0, 0)))
    if pad_k:
        kh = jnp.pad(kh, ((0, 0), (0, pad_k), (0, 0)))
        vh = jnp.pad(vh, ((0, 0), (0, pad_k), (0, 0)))

    # delta = rowsum(dO * O) (the FA2 preprocess; cheap, done in XLA).
    delta = jnp.sum(doh.astype(jnp.float32) * oh.astype(jnp.float32), axis=-1)

    common = dict(block_q=block_q, block_k=block_k, causal=causal,
                  sm_scale=float(scale), q_offset=q_offset, seq_k=sk,
                  pad_k=pad_k)

    dq = pl.pallas_call(
        functools.partial(_dq_kernel, num_k_blocks=num_k, **common),
        grid=(batch * h, num_q, num_k),
        in_specs=[
            pl.BlockSpec((1, block_q, d), lambda bh, i, j: (bh, i, 0)),
            pl.BlockSpec((1, block_k, d), lambda bh, i, j, rep=rep: (bh // rep, j, 0)),
            pl.BlockSpec((1, block_k, d), lambda bh, i, j, rep=rep: (bh // rep, j, 0)),
            pl.BlockSpec((1, block_q, d), lambda bh, i, j: (bh, i, 0)),
            pl.BlockSpec((1, block_q), lambda bh, i, j: (bh, i)),
            pl.BlockSpec((1, block_q), lambda bh, i, j: (bh, i)),
        ],
        out_specs=pl.BlockSpec((1, block_q, d), lambda bh, i, j: (bh, i, 0)),
        out_shape=jax.ShapeDtypeStruct((batch * h, num_q * block_q, d), q.dtype),
        scratch_shapes=[pltpu.VMEM((block_q, d), jnp.float32)],
        interpret=interpret,
    )(qh, kh, vh, doh, lse, delta)

    # dk/dv at q-head granularity; sum the rep partials afterwards.
    dk_p, dv_p = pl.pallas_call(
        functools.partial(_dkv_kernel, num_q_blocks=num_q, **common),
        grid=(batch * h, num_k, num_q),
        in_specs=[
            pl.BlockSpec((1, block_q, d), lambda bh, j, i: (bh, i, 0)),
            pl.BlockSpec((1, block_k, d), lambda bh, j, i, rep=rep: (bh // rep, j, 0)),
            pl.BlockSpec((1, block_k, d), lambda bh, j, i, rep=rep: (bh // rep, j, 0)),
            pl.BlockSpec((1, block_q, d), lambda bh, j, i: (bh, i, 0)),
            pl.BlockSpec((1, block_q), lambda bh, j, i: (bh, i)),
            pl.BlockSpec((1, block_q), lambda bh, j, i: (bh, i)),
        ],
        out_specs=[
            pl.BlockSpec((1, block_k, d), lambda bh, j, i: (bh, j, 0)),
            pl.BlockSpec((1, block_k, d), lambda bh, j, i: (bh, j, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((batch * h, num_k * block_k, d), k.dtype),
            jax.ShapeDtypeStruct((batch * h, num_k * block_k, d), v.dtype),
        ],
        scratch_shapes=[
            pltpu.VMEM((block_k, d), jnp.float32),
            pltpu.VMEM((block_k, d), jnp.float32),
        ],
        interpret=interpret,
    )(qh, kh, vh, doh, lse, delta)

    def unhead(x, heads, s):
        return x[:, :s, :].reshape(batch, heads, s, d).transpose(0, 2, 1, 3)

    dq = unhead(dq, h, sq)
    # Sum GQA partials: [B*H, Sk, d] -> [B, Hkv, rep, Sk, d] -> sum rep.
    dk = dk_p[:, :sk, :].reshape(batch, hkv, rep, sk, d).sum(axis=2).transpose(0, 2, 1, 3)
    dv = dv_p[:, :sk, :].reshape(batch, hkv, rep, sk, d).sum(axis=2).transpose(0, 2, 1, 3)
    return dq, dk.astype(k.dtype), dv.astype(v.dtype)
