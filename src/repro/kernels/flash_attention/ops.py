"""Public entry point for the fused flash-attention kernel.

``flash_attention`` dispatches between:
  * the Pallas TPU kernels (``impl='pallas'``; ``interpret=True`` on CPU) —
    fused forward (saves base-2 LSE) + FlashAttention-2 backward kernels
    (``kernel_bwd.py``: dq and dk/dv grids, P recomputed per tile);
  * the scan-based pure-jnp SystolicAttention (``impl='jnp'``) — identical
    algorithm, lowers on every backend; used by the multi-pod dry-run; its
    backward is autodiff-of-recompute (same FA2 memory profile).
"""

from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp

from repro.core.attention import systolic_attention
from .kernel import DEFAULT_BLOCK_K, DEFAULT_BLOCK_Q, flash_attention_fwd
from .kernel_bwd import flash_attention_bwd


def _jnp_forward(q, k, v, *, causal, scale, q_offset, block_q, block_k,
                 exp2_impl, num_segments):
    return systolic_attention(
        q, k, v,
        causal=causal, scale=scale, q_offset=q_offset,
        block_q=block_q, block_k=block_k,
        exp2_impl=exp2_impl, num_segments=num_segments,
    )


@functools.partial(
    jax.custom_vjp,
    nondiff_argnums=(3, 4, 5, 6, 7, 8, 9, 10, 11),
)
def flash_attention(
    q: jax.Array,
    k: jax.Array,
    v: jax.Array,
    causal: bool = False,
    scale: Optional[float] = None,
    q_offset: int = 0,
    block_q: int = DEFAULT_BLOCK_Q,
    block_k: int = DEFAULT_BLOCK_K,
    exp2_impl: str = "exact",
    num_segments: int = 8,
    impl: str = "jnp",
    interpret: bool = False,
) -> jax.Array:
    """Fused attention, [B,S,H,d] layout, GQA-aware.  Differentiable."""
    if impl == "pallas":
        return flash_attention_fwd(
            q, k, v,
            causal=causal, scale=scale, q_offset=q_offset,
            block_q=block_q, block_k=block_k,
            exp2_impl=exp2_impl, num_segments=num_segments,
            interpret=interpret,
        )
    return _jnp_forward(
        q, k, v,
        causal=causal, scale=scale, q_offset=q_offset,
        block_q=block_q, block_k=block_k,
        exp2_impl=exp2_impl, num_segments=num_segments,
    )


def _fwd(q, k, v, causal, scale, q_offset, block_q, block_k,
         exp2_impl, num_segments, impl, interpret):
    if impl == "pallas":
        out, lse = flash_attention_fwd(
            q, k, v,
            causal=causal, scale=scale, q_offset=q_offset,
            block_q=block_q, block_k=block_k,
            exp2_impl=exp2_impl, num_segments=num_segments,
            interpret=interpret, return_lse=True,
        )
        return out, (q, k, v, out, lse)
    out = _jnp_forward(
        q, k, v,
        causal=causal, scale=scale, q_offset=q_offset,
        block_q=block_q, block_k=block_k,
        exp2_impl=exp2_impl, num_segments=num_segments,
    )
    return out, (q, k, v, None, None)


def _bwd(causal, scale, q_offset, block_q, block_k, exp2_impl,
         num_segments, impl, interpret, res, g):
    q, k, v, out, lse = res
    if impl == "pallas":
        # FlashAttention-2 backward kernels: P recomputed per VMEM tile
        # from the saved LSE; gradients flow through exact exp2 (the PWL
        # forward is a device-numerics detail, as FSA training would pair
        # with an exact-gradient backward).
        return flash_attention_bwd(
            q, k, v, out, lse, g,
            causal=causal, scale=scale, q_offset=q_offset,
            block_q=block_q, block_k=block_k, interpret=interpret,
        )
    # jnp path: differentiate the tiled forward (recompute; XLA fuses).
    f = functools.partial(
        _jnp_forward,
        causal=causal, scale=scale, q_offset=q_offset,
        block_q=block_q, block_k=block_k,
        exp2_impl="exact", num_segments=num_segments,
    )
    _, vjp = jax.vjp(f, q, k, v)
    return vjp(g)


flash_attention.defvjp(_fwd, _bwd)
