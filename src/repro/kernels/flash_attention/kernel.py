"""Fused FlashAttention forward as a Pallas TPU kernel — the TPU-native
realization of the paper's SystolicAttention schedule (DESIGN.md §2).

The paper fuses QKᵀ → online softmax → PV inside one systolic array so no
intermediate ever leaves the array.  On TPU the equivalent is one Pallas
kernel whose S/P tiles never leave VMEM:

  * grid = (batch·heads, num_q_blocks, num_k_blocks); the KV dimension is
    innermost, so the fp32 running statistics (m, l) and the output
    accumulator live in VMEM scratch across KV steps — the analogue of the
    CMP-row registers and the accumulation SRAM;
  * Br = Bc = 128 blocks match the paper's §3.5 tiling (= MXU tile);
  * softmax uses exp2 with the 1/sqrt(d) scale folded into the exp2
    argument — *exactly* Algorithm 1's operation order (rowmax on unscaled
    scores), preserving the paper's numerics claims;
  * optionally the 8-segment PWL exp2 (paper §3.3) computed with the same
    slope/intercept MAC formulation, on the VPU;
  * GQA without materializing repeated KV heads (index_map arithmetic).

The backward pass has its own Pallas kernels (kernel_bwd.py): the forward
optionally emits base-2 log-sum-exp rows, and FlashAttention-2-style dq /
dkv grids recompute P per VMEM tile from the LSE — S/P are never stored.
"""

from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.core.pwl_exp2 import LOG2_E, packed_coeff_table, pwl_coeffs

NEG_INF = -1e30
DEFAULT_BLOCK_Q = 128
DEFAULT_BLOCK_K = 128


def _exp2_inline(
    x: jax.Array, exp2_impl: str, num_segments: int, tables=None
) -> jax.Array:
    """exp2 on a VMEM-resident fp32 tile; 'pwl' follows §3.3 bit-for-bit."""
    if exp2_impl == "exact":
        return jnp.exp2(x)
    x_i = jnp.ceil(x)
    x_f = x - x_i
    idx = jnp.clip(
        jnp.floor((x_f + 1.0) * num_segments).astype(jnp.int32), 0, num_segments - 1
    )
    # Vectorized one-hot segment select (bit-identical to an unrolled
    # where-chain) — one compare + two MAC reductions on the VPU; mirrors
    # the hardware streaming slope/intercept into the PE rows.
    slope, intercept = pwl_coeffs(idx, num_segments, tables)
    frac = slope * x_f + intercept  # the PE-MAC step
    e = jnp.clip(x_i, -150.0, 127.0).astype(jnp.int32)
    out = jnp.ldexp(frac, e)
    return jnp.where(x_i < -148, 0.0, out)


def _fwd_kernel(
    q_ref,  # [1, block_q, d]
    k_ref,  # [1, block_k, d]
    v_ref,  # [1, block_k, d]
    *refs,  # [coeff_ref [2, lanes] if pwl], o_ref, [lse_ref], scratch
    num_k_blocks: int,
    block_q: int,
    block_k: int,
    causal: bool,
    sm_scale: float,
    q_offset: int,
    exp2_impl: str,
    num_segments: int,
    seq_k: int,
    with_lse: bool,
):
    tables = None
    if exp2_impl == "pwl":
        coeff_ref, *refs = refs
        tables = (
            coeff_ref[0, :num_segments],
            coeff_ref[1, :num_segments],
        )
    if with_lse:
        o_ref, lse_ref, m_scr, l_scr, acc_scr = refs
    else:
        o_ref, m_scr, l_scr, acc_scr = refs
        lse_ref = None
    j = pl.program_id(2)
    i = pl.program_id(1)

    @pl.when(j == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    c = sm_scale * LOG2_E  # folded scale (Algorithm 1 lines 10/12)

    # Causal: whole KV blocks strictly above the diagonal contribute nothing;
    # keep the arithmetic but mask (grid steps still run — masked lanes).
    q = q_ref[0].astype(jnp.float32)  # [bq, d]
    k = k_ref[0].astype(jnp.float32)  # [bk, d]
    s = jax.lax.dot_general(
        q, k, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
    )  # [bq, bk] — unscaled S, as in Algorithm 1 line 6

    cols = j * block_k + jax.lax.broadcasted_iota(jnp.int32, (block_q, block_k), 1)
    if seq_k % block_k != 0:
        s = jnp.where(cols < seq_k, s, NEG_INF)
    if causal:
        rows = (
            i * block_q
            + q_offset
            + jax.lax.broadcasted_iota(jnp.int32, (block_q, block_k), 0)
        )
        s = jnp.where(rows >= cols, s, NEG_INF)

    old_m = m_scr[...]
    local_m = jnp.max(s, axis=-1)
    new_m = jnp.maximum(local_m, old_m)                      # line 8
    b = _exp2_inline(c * (old_m - new_m), exp2_impl, num_segments, tables)  # line 10
    p = _exp2_inline(c * (s - new_m[:, None]), exp2_impl, num_segments, tables)  # line 12
    l_scr[...] = l_scr[...] * b + jnp.sum(p, axis=-1)        # lines 13-14
    v = v_ref[0].astype(jnp.float32)
    local_o = jax.lax.dot_general(
        p, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32
    )
    acc_scr[...] = acc_scr[...] * b[:, None] + local_o       # line 16
    m_scr[...] = new_m

    @pl.when(j == num_k_blocks - 1)
    def _finalize():  # line 21: O_i = diag(l)^-1 O
        l = l_scr[...]
        safe_l = jnp.where(l == 0.0, 1.0, l)
        o_ref[0, :, :] = (acc_scr[...] / safe_l[:, None]).astype(o_ref.dtype)
        if with_lse:
            # Base-2 LSE with the scale folded in: P = exp2(c*S - LSE) is
            # the *normalized* probability the backward recomputes.
            lse_ref[0, :] = c * m_scr[...] + jnp.log2(safe_l)


def flash_attention_fwd(
    q: jax.Array,  # [B, Sq, H, d]
    k: jax.Array,  # [B, Sk, Hkv, d]
    v: jax.Array,  # [B, Sk, Hkv, d]
    *,
    causal: bool = False,
    scale: Optional[float] = None,
    q_offset: int = 0,
    block_q: int = DEFAULT_BLOCK_Q,
    block_k: int = DEFAULT_BLOCK_K,
    exp2_impl: str = "exact",
    num_segments: int = 8,
    interpret: bool = False,
    return_lse: bool = False,
):
    batch, sq, h, d = q.shape
    _, sk, hkv, _ = k.shape
    assert h % hkv == 0
    rep = h // hkv
    if scale is None:
        scale = 1.0 / float(np.sqrt(d))

    block_q = min(block_q, sq)
    block_k = min(block_k, sk)
    num_q = -(-sq // block_q)
    num_k = -(-sk // block_k)
    pad_q = num_q * block_q - sq
    pad_k = num_k * block_k - sk

    # [B,S,H,d] -> [B*H, S, d] head-major layout for clean 2D blocks.
    qh = q.transpose(0, 2, 1, 3).reshape(batch * h, sq, d)
    kh = k.transpose(0, 2, 1, 3).reshape(batch * hkv, sk, d)
    vh = v.transpose(0, 2, 1, 3).reshape(batch * hkv, sk, d)
    if pad_q:
        qh = jnp.pad(qh, ((0, 0), (0, pad_q), (0, 0)))
    if pad_k:
        kh = jnp.pad(kh, ((0, 0), (0, pad_k), (0, 0)))
        vh = jnp.pad(vh, ((0, 0), (0, pad_k), (0, 0)))

    grid = (batch * h, num_q, num_k)

    kernel = functools.partial(
        _fwd_kernel,
        with_lse=return_lse,
        num_k_blocks=num_k,
        block_q=block_q,
        block_k=block_k,
        causal=causal,
        sm_scale=float(scale),
        q_offset=q_offset,
        exp2_impl=exp2_impl,
        num_segments=num_segments,
        seq_k=sk,
    )

    in_specs = [
        pl.BlockSpec((1, block_q, d), lambda bh, i, j: (bh, i, 0)),
        # GQA: map q-head bh -> kv-head bh // rep without materializing.
        pl.BlockSpec((1, block_k, d), lambda bh, i, j, rep=rep: (bh // rep, j, 0)),
        pl.BlockSpec((1, block_k, d), lambda bh, i, j, rep=rep: (bh // rep, j, 0)),
    ]
    operands = [qh, kh, vh]
    if exp2_impl == "pwl":
        # PWL slope/intercept table as a (replicated, lane-aligned) operand:
        # Pallas kernels reject captured constant arrays.
        coeffs = jnp.asarray(packed_coeff_table(num_segments))
        in_specs.append(
            pl.BlockSpec(coeffs.shape, lambda bh, i, j: (0, 0))
        )
        operands.append(coeffs)

    out = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=in_specs,
        out_specs=(
            [
                pl.BlockSpec((1, block_q, d), lambda bh, i, j: (bh, i, 0)),
                pl.BlockSpec((1, block_q), lambda bh, i, j: (bh, i)),
            ]
            if return_lse
            else pl.BlockSpec((1, block_q, d), lambda bh, i, j: (bh, i, 0))
        ),
        out_shape=(
            [
                jax.ShapeDtypeStruct((batch * h, num_q * block_q, d), q.dtype),
                jax.ShapeDtypeStruct((batch * h, num_q * block_q), jnp.float32),
            ]
            if return_lse
            else jax.ShapeDtypeStruct((batch * h, num_q * block_q, d), q.dtype)
        ),
        scratch_shapes=[
            pltpu.VMEM((block_q,), jnp.float32),
            pltpu.VMEM((block_q,), jnp.float32),
            pltpu.VMEM((block_q, d), jnp.float32),
        ],
        interpret=interpret,
    )(*operands)

    if return_lse:
        out, lse = out
        o = out[:, :sq, :].reshape(batch, h, sq, d).transpose(0, 2, 1, 3)
        return o, lse
    out = out[:, :sq, :].reshape(batch, h, sq, d).transpose(0, 2, 1, 3)
    return out
