"""Jitted wrapper for the PWL exp2 kernel."""
import functools
import jax
from .kernel import pwl_exp2_pallas

pwl_exp2 = jax.jit(
    functools.partial(pwl_exp2_pallas, interpret=False),
    static_argnames=("num_segments", "block_rows"),
)
