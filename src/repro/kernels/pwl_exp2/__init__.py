from .kernel import pwl_exp2_pallas  # noqa: F401
from .ref import pwl_exp2_reference  # noqa: F401
