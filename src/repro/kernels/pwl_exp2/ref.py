"""jnp oracle for the PWL exp2 Pallas kernel: repro.core.pwl_exp2.pwl_exp2."""
from repro.core.pwl_exp2 import pwl_exp2 as pwl_exp2_reference  # noqa: F401
