"""Standalone Pallas kernel for the FSA piecewise-linear exp2 (paper §3.3).

Elementwise exp2 over a tiled array with the 8-segment chord interpolation:
Split-unit decomposition (x = x_i + x_f), one MAC per element
(slope_k * x_f + intercept_k) and an exponent-field update for 2**x_i.
Blocked into VMEM tiles of (block_rows, 128) — lane-aligned for the VPU.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.core.pwl_exp2 import packed_coeff_table, pwl_coeffs

DEFAULT_BLOCK_ROWS = 256
LANES = 128


def _kernel(x_ref, coeff_ref, o_ref, *, num_segments: int):
    x = x_ref[...].astype(jnp.float32)
    x_i = jnp.ceil(x)
    x_f = x - x_i
    idx = jnp.clip(
        jnp.floor((x_f + 1.0) * num_segments).astype(jnp.int32), 0, num_segments - 1
    )
    # One-hot segment select (see core.pwl_exp2.pwl_coeffs): vectorized and
    # bit-identical to the unrolled where-chain it replaces.  The table
    # arrives as a lane-aligned operand (kernels can't capture constants).
    tables = (coeff_ref[0, :num_segments], coeff_ref[1, :num_segments])
    slope, intercept = pwl_coeffs(idx, num_segments, tables)
    frac = slope * x_f + intercept
    e = jnp.clip(x_i, -150.0, 127.0).astype(jnp.int32)
    out = jnp.where(x_i < -148, 0.0, jnp.ldexp(frac, e))
    o_ref[...] = out.astype(o_ref.dtype)


def pwl_exp2_pallas(
    x: jax.Array,
    *,
    num_segments: int = 8,
    block_rows: int = DEFAULT_BLOCK_ROWS,
    interpret: bool = False,
) -> jax.Array:
    """PWL exp2 over an arbitrary-shaped array (x <= 0)."""
    orig_shape, orig_dtype = x.shape, x.dtype
    flat = x.reshape(-1)
    n = flat.shape[0]
    per_block = block_rows * LANES
    num_blocks = -(-n // per_block)
    padded = num_blocks * per_block
    if padded != n:
        flat = jnp.pad(flat, (0, padded - n))
    tiled = flat.reshape(num_blocks * block_rows, LANES)

    coeffs = jnp.asarray(packed_coeff_table(num_segments, LANES))
    out = pl.pallas_call(
        functools.partial(_kernel, num_segments=num_segments),
        grid=(num_blocks,),
        in_specs=[
            pl.BlockSpec((block_rows, LANES), lambda i: (i, 0)),
            pl.BlockSpec(coeffs.shape, lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((block_rows, LANES), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct(tiled.shape, orig_dtype),
        interpret=interpret,
    )(tiled, coeffs)
    return out.reshape(-1)[:n].reshape(orig_shape)
