"""Forward-compat shims for the modern JAX mesh/sharding surface.

The codebase (and its tests) are written against the current JAX API:

  * ``jax.set_mesh(mesh)`` as a context manager,
  * ``jax.make_mesh(..., axis_types=...)``,
  * ``jax.sharding.AxisType``,
  * ``jax.sharding.get_abstract_mesh()`` for the ambient mesh,
  * ``jax.shard_map(f, in_specs=..., out_specs=...)`` resolving the mesh
    from the ambient context.

Older jaxlib builds (0.4.x, as baked into this container) expose the same
functionality under different names: ``Mesh.__enter__`` for the ambient
resource env, ``jax.experimental.shard_map.shard_map`` with an explicit
mesh argument, and no ``AxisType`` at all.  ``ensure()`` installs thin
adapters for whichever pieces are missing; on a current JAX it is a no-op.

Every patch is guarded on attribute absence, so upgrading JAX silently
retires the shim.
"""

from __future__ import annotations

import enum
import inspect
import math

import jax


def _ambient_physical_mesh():
    """The mesh installed by ``with mesh:`` / our ``set_mesh`` shim."""
    from jax._src import mesh as mesh_lib

    return mesh_lib.thread_resources.env.physical_mesh


class _AxisType(enum.Enum):
    Auto = "auto"
    Explicit = "explicit"
    Manual = "manual"


class _MeshContext:
    """``with jax.set_mesh(mesh):`` adapter over ``Mesh.__enter__``."""

    def __init__(self, mesh):
        self.mesh = mesh

    def __enter__(self):
        self.mesh.__enter__()
        return self.mesh

    def __exit__(self, *exc):
        return self.mesh.__exit__(*exc)


def _make_mesh_shim(orig):
    def make_mesh(axis_shapes, axis_names, *, devices=None, axis_types=None):
        del axis_types  # old jax: all axes behave as Auto
        if devices is None:
            n = math.prod(axis_shapes)
            all_devices = jax.devices()
            if n != len(all_devices):
                devices = all_devices[:n]
        return orig(axis_shapes, axis_names, devices=devices)

    return make_mesh


def _shard_map_shim(f, *, mesh=None, in_specs, out_specs, check_rep=False,
                    **kwargs):
    """New-style ``jax.shard_map``: mesh optional, taken from the ambient
    context at call time (the mesh is entered around the jit that traces
    the shard_map, so it is visible while tracing)."""
    from jax.experimental.shard_map import shard_map as _shard_map

    del kwargs  # newer-API extras (axis_names=...) have no 0.4.x analogue

    def call(*args):
        m = mesh if mesh is not None else _ambient_physical_mesh()
        if m is None or m.empty:
            raise ValueError(
                "shard_map: no mesh found — pass mesh= or enter "
                "`with jax.set_mesh(mesh):`"
            )
        return _shard_map(
            f, m, in_specs=in_specs, out_specs=out_specs, check_rep=False
        )(*args)

    return call


def ensure() -> None:
    """Install the missing pieces of the modern mesh API (idempotent)."""
    if not hasattr(jax.sharding, "AxisType"):
        jax.sharding.AxisType = _AxisType

    if not hasattr(jax.sharding, "get_abstract_mesh"):
        jax.sharding.get_abstract_mesh = _ambient_physical_mesh

    if not hasattr(jax, "set_mesh"):
        jax.set_mesh = _MeshContext

    if not hasattr(jax, "shard_map"):
        jax.shard_map = _shard_map_shim

    if (
        hasattr(jax, "make_mesh")
        and not getattr(jax.make_mesh, "_repro_compat", False)
        and "axis_types" not in inspect.signature(jax.make_mesh).parameters
    ):
        shim = _make_mesh_shim(jax.make_mesh)
        shim._repro_compat = True
        jax.make_mesh = shim
