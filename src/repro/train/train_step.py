"""Training step factory: value_and_grad + optimizer, with optional
microbatch gradient accumulation and optional int8-compressed DP reduction.

All functions are pure and pjit-able; sharding comes from in/out_shardings
at jit time (see launch/dryrun.py and launch/train.py).
"""

from __future__ import annotations

import functools
from typing import Any, Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models.model import lm_loss
from repro.optim.grad_compress import compress_with_feedback, dequantize_int8


def make_train_step(
    cfg: ModelConfig,
    optimizer,
    *,
    num_microbatches: int = 1,
    compress_grads: bool = False,
):
    """Returns train_step(params, opt_state, batch [, residual]) -> ..."""

    def loss_fn(params, batch):
        return lm_loss(params, cfg, batch)

    def compute_grads(params, batch):
        if num_microbatches == 1:
            return jax.value_and_grad(loss_fn)(params, batch)

        def split(x):
            mb = x.shape[0] // num_microbatches
            return x.reshape(num_microbatches, mb, *x.shape[1:])

        micro = jax.tree.map(split, batch)

        def body(carry, mb):
            loss_acc, grad_acc = carry
            loss, g = jax.value_and_grad(loss_fn)(params, mb)
            grad_acc = jax.tree.map(jnp.add, grad_acc, g)
            return (loss_acc + loss, grad_acc), None

        zero = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
        (loss_sum, grads), _ = jax.lax.scan(body, (jnp.zeros(()), zero), micro)
        inv = 1.0 / num_microbatches
        return loss_sum * inv, jax.tree.map(lambda g: g * inv, grads)

    if not compress_grads:
        def train_step(params, opt_state, batch):
            loss, grads = compute_grads(params, batch)
            new_params, new_opt = optimizer.update(grads, opt_state, params)
            gnorm = jnp.sqrt(
                sum(jnp.sum(jnp.square(g.astype(jnp.float32))) for g in jax.tree.leaves(grads))
            )
            return new_params, new_opt, {"loss": loss, "grad_norm": gnorm}

        return train_step

    def train_step_compressed(params, opt_state, batch, residual):
        loss, grads = compute_grads(params, batch)
        # int8 quantization with error feedback before the (cross-pod) grad
        # reduction XLA derives from the sharding; the dequantized values
        # feed the optimizer, the quantization error carries to next step.
        q, scales, new_residual = compress_with_feedback(grads, residual)
        grads = jax.tree.map(dequantize_int8, q, scales)
        new_params, new_opt = optimizer.update(grads, opt_state, params)
        gnorm = jnp.sqrt(
            sum(jnp.sum(jnp.square(g.astype(jnp.float32))) for g in jax.tree.leaves(grads))
        )
        return new_params, new_opt, new_residual, {"loss": loss, "grad_norm": gnorm}

    return train_step_compressed


def make_eval_step(cfg: ModelConfig):
    def eval_step(params, batch):
        return lm_loss(params, cfg, batch)

    return eval_step
