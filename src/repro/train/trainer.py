"""Training loop with checkpoint/restart, preemption handling, straggler
watchdog, async checkpointing, and deterministic data — the glue layer that
makes the framework runnable unattended.

Single-process on this container; every policy (atomic checkpoints, resume
from latest, watchdog thresholds, preemption drain) is the multi-host one.

Telemetry (``repro.obs``): each step lands in the trainer's metrics
registry (``train_steps_total``/``train_tokens_total`` counters,
``train_step_seconds`` histogram, loss/grad-norm gauges, per-step MFU
against the paper's FSA array) and, when ``TrainerConfig.metrics_jsonl``
is set, as one structured JSONL record per step — the stream
``launch/scrape_log.py`` now parses without regexes.  The human log line
is kept.  Spans go to the ambient tracer (``--trace-out`` installs one).
"""

from __future__ import annotations

import contextlib
import dataclasses
import json
import time
from typing import Callable, Optional

_NULL_CTX = contextlib.nullcontext()

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint import CheckpointManager
from repro.configs.base import ModelConfig, ShapeConfig
from repro.data import DataConfig, make_source
from repro.dist.fault import PreemptionHandler, StepWatchdog
from repro.models import init_params, lm_loss
from repro.obs import MFUMeter, Registry, get_tracer
from repro.optim import make_optimizer
from repro.optim.grad_compress import init_residual
from repro.optim.schedules import cosine_with_warmup
from .train_step import make_train_step


@dataclasses.dataclass
class TrainerConfig:
    total_steps: int = 100
    ckpt_every: int = 20
    ckpt_dir: str = "/tmp/repro_ckpt"
    keep: int = 3
    optimizer: str = "adamw"
    peak_lr: float = 3e-4
    warmup_steps: int = 10
    num_microbatches: int = 1
    log_every: int = 10
    seed: int = 0
    watchdog_factor: float = 10.0
    # int8-compressed DP gradient reduction with error feedback
    # (repro.optim.grad_compress); adds a residual pytree to the state.
    compress_grads: bool = False
    # One JSON object per step appended to this path (None: no stream);
    # the structured twin of the stdout log line — scrape_log's fast path.
    metrics_jsonl: Optional[str] = None


class Trainer:
    def __init__(
        self,
        cfg: ModelConfig,
        shape: ShapeConfig,
        tcfg: TrainerConfig,
        *,
        token_file: Optional[str] = None,
        hooks: Optional[dict[str, Callable]] = None,
        mesh=None,
        registry: Optional[Registry] = None,  # repro.obs metrics sink
        tracer=None,  # repro.obs Tracer (default: ambient, usually Null)
    ):
        self.cfg, self.shape, self.tcfg = cfg, shape, tcfg
        self.data = make_source(cfg, shape, DataConfig(seed=tcfg.seed), token_file)
        self.ckpt = CheckpointManager(tcfg.ckpt_dir, keep=tcfg.keep)
        self.registry = registry if registry is not None else Registry()
        self.tracer = tracer if tracer is not None else get_tracer()
        self.watchdog = StepWatchdog(
            timeout_factor=tcfg.watchdog_factor, registry=self.registry
        )
        self.preempt = PreemptionHandler(install=False, registry=self.registry)
        self.hooks = hooks or {}
        self.mesh = mesh
        self.mfu = MFUMeter(cfg, self.registry)
        self._steps_total = self.registry.counter(
            "train_steps_total", "optimizer steps completed"
        )
        self._tokens_total = self.registry.counter(
            "train_tokens_total", "tokens consumed"
        )
        self._h_step = self.registry.histogram(
            "train_step_seconds", "wall time per optimizer step"
        )
        self._g_loss = self.registry.gauge("train_loss", "last step loss")
        self._g_gnorm = self.registry.gauge(
            "train_grad_norm", "last step gradient norm"
        )
        self._g_tok_s = self.registry.gauge(
            "train_tokens_per_s", "throughput of the last step"
        )

        sched = cosine_with_warmup(tcfg.peak_lr, tcfg.warmup_steps, tcfg.total_steps)
        self.optimizer = make_optimizer(tcfg.optimizer, lr=sched)
        step = make_train_step(
            cfg,
            self.optimizer,
            num_microbatches=tcfg.num_microbatches,
            compress_grads=tcfg.compress_grads,
        )
        self.step_fn = jax.jit(step)

    def _shard_state(self, state: dict) -> dict:
        """Place params (and the compression residual) per the TP rules when
        a mesh is given; the jit then reads the layout off the arrays."""
        if self.mesh is None:
            return state
        from repro.dist.sharding import param_shardings

        sh = param_shardings(state["params"], self.cfg, self.mesh)
        out = dict(state)
        out["params"] = jax.device_put(state["params"], sh)
        if "residual" in state:
            out["residual"] = jax.device_put(state["residual"], sh)
        return out

    # -- state ------------------------------------------------------------

    def init_state(self) -> dict:
        params = init_params(self.cfg, jax.random.PRNGKey(self.tcfg.seed))
        state = {
            "params": params,
            "opt": self.optimizer.init(params),
            "step": 0,
        }
        if self.tcfg.compress_grads:
            state["residual"] = init_residual(params)
        return state

    def restore_or_init(self) -> dict:
        latest = self.ckpt.latest_step()
        if latest is None:
            return self.init_state()
        template = jax.tree.map(
            lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype),
            {k: v for k, v in self.init_state().items() if k != "step"},
        )
        restored = self.ckpt.restore(latest, template)
        restored["step"] = latest
        return restored

    # -- loop --------------------------------------------------------------

    def run(self, state: Optional[dict] = None) -> dict:
        state = self._shard_state(state or self.restore_or_init())
        ckpt_keys = ("params", "opt") + (
            ("residual",) if self.tcfg.compress_grads else ()
        )
        mesh_ctx = self.mesh or _NULL_CTX
        losses = []
        tokens_per_batch = self.shape.global_batch * self.shape.seq_len
        jsonl = (
            open(self.tcfg.metrics_jsonl, "a")
            if self.tcfg.metrics_jsonl else None
        )
        while state["step"] < self.tcfg.total_steps:
            if self.preempt.requested:
                self.ckpt.save(state["step"], {k: state[k] for k in ckpt_keys})
                break
            step = state["step"]
            batch = {k: jnp.asarray(v) for k, v in self.data.batch(step).items()}
            self.watchdog.start_step()
            with mesh_ctx, self.tracer.span(
                "train_step", cat="train", tid=0, args={"step": step}
            ):
                if self.tcfg.compress_grads:
                    params, opt, residual, metrics = self.step_fn(
                        state["params"], state["opt"], batch, state["residual"]
                    )
                    new_state = {
                        "params": params, "opt": opt,
                        "residual": residual, "step": step + 1,
                    }
                else:
                    params, opt, metrics = self.step_fn(
                        state["params"], state["opt"], batch
                    )
                    new_state = {"params": params, "opt": opt, "step": step + 1}
                jax.block_until_ready(metrics["loss"])
            dur = self.watchdog.end_step()
            state = new_state
            loss = float(metrics["loss"])
            gnorm = float(metrics["grad_norm"])
            losses.append(loss)
            self._steps_total.inc()
            self._tokens_total.inc(tokens_per_batch)
            self._h_step.observe(dur)
            self._g_loss.set(loss)
            self._g_gnorm.set(gnorm)
            self._g_tok_s.set(tokens_per_batch / dur)
            mfu_rec = self.mfu.train_step(
                self.shape.global_batch, self.shape.seq_len, dur
            )
            if jsonl is not None:
                jsonl.write(json.dumps({
                    "event": "train_step",
                    "step": step + 1,
                    "loss": loss,
                    "grad_norm": gnorm,
                    "step_s": dur,
                    "tokens_per_s": tokens_per_batch / dur,
                    "mfu": mfu_rec["mfu"],
                    "model_flops_per_s": mfu_rec["flops_per_s"],
                }) + "\n")
                jsonl.flush()
            if "on_step" in self.hooks:
                self.hooks["on_step"](state, metrics)
            if (step + 1) % self.tcfg.log_every == 0:
                print(
                    f"step {step + 1} loss {loss:.4f} "
                    f"gnorm {gnorm:.3f} {dur * 1e3:.0f} ms"
                )
            if (step + 1) % self.tcfg.ckpt_every == 0:
                self.ckpt.save_async(step + 1, {k: state[k] for k in ckpt_keys})
        if jsonl is not None:
            jsonl.close()
        self.ckpt.wait()
        state["losses"] = losses
        return state
