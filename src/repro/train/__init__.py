from .train_step import make_eval_step, make_train_step  # noqa: F401
