"""Fault-tolerant checkpointing.

Properties required at 1000+ nodes, implemented here:

  * **atomic**: writes go to ``step_N.tmp/`` then ``os.rename`` to
    ``step_N/`` — a crash mid-save never corrupts the latest checkpoint;
  * **async**: ``save_async`` snapshots device arrays to host then writes in
    a background thread so the train loop keeps stepping;
  * **sharded**: each host writes only its address-able shards (single-host
    here, but the layout is per-leaf .npy + a msgpack manifest keyed by
    pytree path, exactly what a multi-host writer partitions);
  * **elastic**: ``restore`` takes a target pytree of ShapeDtypeStructs (or
    shardings) and re-shards on load with ``jax.device_put`` — resuming on
    a different mesh shape Just Works;
  * **retention**: keep the newest ``keep`` checkpoints, delete older.
"""

from __future__ import annotations

import json
import os
import shutil
import threading
from typing import Any, Optional

import jax
import numpy as np


def _flatten_with_paths(tree: Any) -> dict[str, Any]:
    flat = {}
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        key = "/".join(str(getattr(p, "key", getattr(p, "idx", getattr(p, "name", p)))) for p in path)
        flat[key] = leaf
    return flat


class CheckpointManager:
    def __init__(self, directory: str, keep: int = 3):
        self.dir = directory
        self.keep = keep
        os.makedirs(directory, exist_ok=True)
        self._lock = threading.Lock()
        self._pending: Optional[threading.Thread] = None

    # -- save -------------------------------------------------------------

    def save(self, step: int, tree: Any) -> str:
        host_tree = jax.tree.map(np.asarray, tree)  # device -> host snapshot
        return self._write(step, host_tree)

    def save_async(self, step: int, tree: Any) -> None:
        host_tree = jax.tree.map(np.asarray, tree)  # snapshot BEFORE returning
        self.wait()
        self._pending = threading.Thread(
            target=self._write, args=(step, host_tree), daemon=True
        )
        self._pending.start()

    def wait(self) -> None:
        if self._pending is not None:
            self._pending.join()
            self._pending = None

    def _write(self, step: int, host_tree: Any) -> str:
        final = os.path.join(self.dir, f"step_{step:010d}")
        tmp = final + ".tmp"
        with self._lock:
            if os.path.exists(tmp):
                shutil.rmtree(tmp)
            os.makedirs(tmp)
            flat = _flatten_with_paths(host_tree)
            manifest = {}
            for i, (key, leaf) in enumerate(sorted(flat.items())):
                fname = f"leaf_{i:06d}.npy"
                np.save(os.path.join(tmp, fname), np.asarray(leaf))
                manifest[key] = fname
            with open(os.path.join(tmp, "manifest.json"), "w") as f:
                json.dump({"step": step, "leaves": manifest}, f)
            if os.path.exists(final):
                shutil.rmtree(final)
            os.rename(tmp, final)  # atomic publish
            self._gc()
        return final

    def _gc(self) -> None:
        steps = sorted(self.all_steps())
        for s in steps[: -self.keep] if self.keep else []:
            shutil.rmtree(os.path.join(self.dir, f"step_{s:010d}"), ignore_errors=True)

    # -- restore -----------------------------------------------------------

    def all_steps(self) -> list[int]:
        out = []
        for name in os.listdir(self.dir):
            if name.startswith("step_") and not name.endswith(".tmp"):
                out.append(int(name.split("_")[1]))
        return sorted(out)

    def latest_step(self) -> Optional[int]:
        steps = self.all_steps()
        return steps[-1] if steps else None

    def restore(self, step: int, target: Any, shardings: Any = None) -> Any:
        """Load into the structure of ``target``; re-shard if requested.

        ``target`` may hold arrays or ShapeDtypeStructs.  ``shardings``
        (same structure, jax.sharding.Sharding leaves) enables elastic
        resume onto a different mesh.
        """
        path = os.path.join(self.dir, f"step_{step:010d}")
        with open(os.path.join(path, "manifest.json")) as f:
            manifest = json.load(f)["leaves"]
        flat_target = _flatten_with_paths(target)
        missing = set(flat_target) - set(manifest)
        extra = set(manifest) - set(flat_target)
        if missing or extra:
            raise ValueError(f"checkpoint mismatch: missing={missing} extra={extra}")
        loaded = {
            key: np.load(os.path.join(path, fname)) for key, fname in manifest.items()
        }
        flat_shard = _flatten_with_paths(shardings) if shardings is not None else {}

        leaves_keys = sorted(flat_target)
        values = []
        for key in leaves_keys:
            arr = loaded[key]
            tgt = flat_target[key]
            if tuple(arr.shape) != tuple(tgt.shape):
                raise ValueError(f"{key}: shape {arr.shape} != target {tgt.shape}")
            arr = arr.astype(tgt.dtype)
            if key in flat_shard:
                arr = jax.device_put(arr, flat_shard[key])
            values.append(arr)
        # Rebuild by path order.
        paths_leaves, treedef = jax.tree_util.tree_flatten_with_path(target)
        key_of = [
            "/".join(str(getattr(p, "key", getattr(p, "idx", getattr(p, "name", p)))) for p in path)
            for path, _ in paths_leaves
        ]
        by_key = dict(zip(leaves_keys, values))
        return jax.tree_util.tree_unflatten(treedef, [by_key[k] for k in key_of])
