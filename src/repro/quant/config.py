"""Quantization policy configuration (pure data, no jax imports).

``QuantConfig`` is the serializable policy carried on ``ModelConfig.quant``
and threaded MaxText-style through every layer: which layer classes run
int8 matmuls, how weights are scaled (per-tensor vs per-output-channel),
and whether the KV cache stores int8 payloads.  It is a frozen dataclass
(hashable) so configs stay valid jit static arguments.
"""

from __future__ import annotations

import dataclasses
from typing import Optional

# Layer classes the policy can name.  Matmuls outside these (embedding
# lookup, lm_head, router, norms) always stay full precision.
LAYER_CLASSES = ("mlp", "attention", "moe", "ssm", "xlstm")


@dataclasses.dataclass(frozen=True)
class QuantConfig:
    """int8 quantization policy.

    Activations are always dynamically quantized **per row** (one symmetric
    scale per token vector) — this is what keeps chunked-prefill and
    per-token decode bit-identical, so the serve engine's token-equivalence
    contract survives quantization.  ``granularity`` controls the weight
    side only.
    """

    dtype: str = "int8"
    granularity: str = "per_channel"  # per_channel | per_tensor (weights)
    layer_classes: tuple[str, ...] = LAYER_CLASSES
    kv_cache: bool = True  # store K/V as int8 with per-token/head scales

    def __post_init__(self):
        if self.dtype != "int8":
            raise ValueError(f"unsupported quant dtype {self.dtype!r}")
        if self.granularity not in ("per_channel", "per_tensor"):
            raise ValueError(f"unknown granularity {self.granularity!r}")
        bad = set(self.layer_classes) - set(LAYER_CLASSES)
        if bad:
            raise ValueError(f"unknown layer classes {sorted(bad)}")

    def active_for(self, layer_class: str) -> bool:
        return layer_class in self.layer_classes


def parse_quant(flag: Optional[str]) -> Optional[QuantConfig]:
    """CLI flag -> policy.

    none            -> None (fully disabled)
    int8            -> per-channel weights + int8 KV cache (the default policy)
    int8-per-tensor -> per-tensor weight scales
    int8-kv-only    -> full-precision matmuls, int8 KV cache only
    int8-no-kv      -> int8 matmuls, full-precision KV cache
    """
    if flag is None or flag in ("none", "fp", "off"):
        return None
    if flag == "int8":
        return QuantConfig()
    if flag == "int8-per-tensor":
        return QuantConfig(granularity="per_tensor")
    if flag == "int8-kv-only":
        return QuantConfig(layer_classes=(), kv_cache=True)
    if flag == "int8-no-kv":
        return QuantConfig(kv_cache=False)
    raise ValueError(f"unknown --quant flag {flag!r}")


QUANT_FLAGS = ("none", "int8", "int8-per-tensor", "int8-kv-only", "int8-no-kv")
