"""int8 KV-cache storage: per-token/per-head symmetric scales.

Each cached K (or V) vector — one (slot, position, kv_head) row of
``head_dim`` values — gets its own fp32 scale, so a token's quantized K/V
is independent of everything else in the cache.  Chunked flash prefill and
the decode scatter-write therefore produce byte-identical cache contents
for the same token, and dequantized attention matches between the two
paths exactly (the token-equivalence contract).

At rest the cache is ``head_dim`` int8 + 4 scale bytes per row vs
``4 * head_dim`` bytes fp32 — a (d + 4)/(4d) footprint, ~3.2x smaller at
d=16 and ~3.8x at d=128 (~2x vs bf16).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from .quantize import INT8_MAX, _EPS


def quantize_kv(x: jax.Array) -> tuple[jax.Array, jax.Array]:
    """[..., d] -> (int8 [..., d], f32 scale [...]): one scale per vector."""
    xf = x.astype(jnp.float32)
    amax = jnp.max(jnp.abs(xf), axis=-1)
    scale = jnp.maximum(amax, _EPS) / INT8_MAX
    q = jnp.clip(
        jnp.round(xf / scale[..., None]), -INT8_MAX, INT8_MAX
    ).astype(jnp.int8)
    return q, scale


def dequantize_kv(q: jax.Array, scale: jax.Array, dtype=jnp.float32) -> jax.Array:
    """Inverse of ``quantize_kv``: int8 payload x per-vector scale."""
    return (q.astype(jnp.float32) * scale[..., None]).astype(dtype)
