"""int8 quantized matmul primitives.

The forward path is integer-domain end to end: dynamic per-row symmetric
int8 quantization of the activation, static-rule symmetric quantization of
the weight (per output channel or per tensor), an int8 x int8 ->
**int32-accumulating** ``lax.dot_general`` (the systolic array's native
low-precision mode), and a per-channel dequant epilogue.

Gradients are straight-through (AQT-style): the backward rule is the plain
fp matmul vjp against the unquantized operands, so the same ``quant.dot``
serves train and serve.

Per-row activation scales are the load-bearing choice: a token's quantized
projection depends only on that token's row, so a chunked-prefill matmul
over [B, C, d] and a decode matmul over [B, 1, d] produce bit-identical
values for the same token — the serve engine's token-equivalence harness
holds under quantization.
"""

from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp

INT8_MAX = 127.0
_EPS = 1e-20


def quantize_int8(x: jax.Array) -> tuple[jax.Array, jax.Array]:
    """Symmetric per-tensor int8 quantization -> (q int8, scalar scale)."""
    scale = jnp.maximum(jnp.max(jnp.abs(x)), _EPS) / INT8_MAX
    q = jnp.clip(jnp.round(x / scale), -INT8_MAX, INT8_MAX).astype(jnp.int8)
    return q, scale


def dequantize_int8(q: jax.Array, scale: jax.Array) -> jax.Array:
    return q.astype(jnp.float32) * scale


def quantize_rows(x: jax.Array, axis: int = -1) -> tuple[jax.Array, jax.Array]:
    """Per-row symmetric int8: one scale per slice along ``axis``.

    Returns (q int8, scale f32 with ``axis`` kept at size 1 for broadcast).
    """
    xf = x.astype(jnp.float32)
    amax = jnp.max(jnp.abs(xf), axis=axis, keepdims=True)
    scale = jnp.maximum(amax, _EPS) / INT8_MAX
    q = jnp.clip(jnp.round(xf / scale), -INT8_MAX, INT8_MAX).astype(jnp.int8)
    return q, scale


def _quantize_weight(w: jax.Array, per_channel: bool, contract_axis: int):
    """Weight scales: per output channel (reduce the contraction axis) or
    one scalar per tensor."""
    wf = w.astype(jnp.float32)
    if per_channel:
        amax = jnp.max(jnp.abs(wf), axis=contract_axis, keepdims=True)
    else:
        amax = jnp.max(jnp.abs(wf))
    scale = jnp.maximum(amax, _EPS) / INT8_MAX
    q = jnp.clip(jnp.round(wf / scale), -INT8_MAX, INT8_MAX).astype(jnp.int8)
    return q, scale


def _int8_dot_impl(x: jax.Array, w: jax.Array, per_channel: bool) -> jax.Array:
    """x [..., d] @ w [d, f] via int8 with int32 accumulation."""
    xq, xs = quantize_rows(x)  # xs [..., 1]
    wq, ws = _quantize_weight(w, per_channel, contract_axis=0)  # ws [1, f] | scalar
    acc = jax.lax.dot_general(
        xq, wq, (((x.ndim - 1,), (0,)), ((), ())),
        preferred_element_type=jnp.int32,
    )
    out = acc.astype(jnp.float32) * xs * jnp.reshape(ws, (-1,))
    return out.astype(x.dtype)


def _make_int8_dot(per_channel: bool):
    @jax.custom_vjp
    def int8_dot(x, w):
        return _int8_dot_impl(x, w, per_channel)

    def fwd(x, w):
        return int8_dot(x, w), (x, w)

    def bwd(res, g):
        # Straight-through: gradients of the fp matmul w.r.t. the
        # unquantized operands (AQT's default training rule).
        x, w = res
        g32 = g.astype(jnp.float32)
        dx = jax.lax.dot_general(
            g32, w.astype(jnp.float32), (((g.ndim - 1,), (1,)), ((), ())),
        )
        x2 = x.astype(jnp.float32).reshape(-1, x.shape[-1])
        g2 = g32.reshape(-1, g.shape[-1])
        dw = x2.T @ g2
        return dx.astype(x.dtype), dw.astype(w.dtype)

    int8_dot.defvjp(fwd, bwd)
    return int8_dot


# Two closed-over variants so jit caches trace each rule once.
_INT8_DOT = {True: _make_int8_dot(True), False: _make_int8_dot(False)}


def int8_dot(x: jax.Array, w: jax.Array, *, per_channel: bool = True) -> jax.Array:
    """Quantized ``x @ w`` (differentiable, straight-through backward)."""
    return _INT8_DOT[per_channel](x, w)


def int8_dot_batched(
    x: jax.Array, w: jax.Array, *, per_channel: bool = True
) -> jax.Array:
    """Expert-batched quantized matmul: x [E, ..., d] @ w [E, d, f].

    vmap over the leading (expert) axis of ``int8_dot`` — custom_vjp
    composes with vmap, so the straight-through backward batches too.
    """
    return jax.vmap(_INT8_DOT[per_channel])(x, w)


def tree_bytes(tree: Any) -> int:
    """Total bytes of every array leaf (cache-footprint accounting)."""
    return sum(
        leaf.size * leaf.dtype.itemsize
        for leaf in jax.tree.leaves(tree)
        if hasattr(leaf, "dtype")
    )
