"""``repro.quant`` — int8 quantization subsystem spanning train and serve.

Pieces:
  * ``QuantConfig`` / ``parse_quant`` — the policy (config.py), carried on
    ``ModelConfig.quant`` and parsed from ``--quant`` CLI flags;
  * ``Quant`` / ``get_quant`` — the MaxText-style object model code calls
    (``quant.dot(x, w, layer_class)``) (policy.py);
  * ``int8_dot`` / ``int8_dot_batched`` — dynamic per-row int8 quantize ->
    int32-accumulating ``lax.dot_general`` -> per-channel dequant epilogue,
    with straight-through gradients (quantize.py);
  * ``quantize_kv`` / ``dequantize_kv`` — int8 KV-cache storage with
    per-token/per-head scales (kv.py);
  * ``quantize_int8`` / ``dequantize_int8`` — per-tensor primitives, also
    the backbone of ``repro.optim.grad_compress``.
"""

from .config import LAYER_CLASSES, QUANT_FLAGS, QuantConfig, parse_quant  # noqa: F401
from .kv import dequantize_kv, quantize_kv  # noqa: F401
from .policy import Quant, get_quant  # noqa: F401
from .quantize import (  # noqa: F401
    dequantize_int8,
    int8_dot,
    int8_dot_batched,
    quantize_int8,
    quantize_rows,
    tree_bytes,
)
