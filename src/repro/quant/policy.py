"""The ``Quant`` policy object threaded through the forward path.

MaxText threads an AQT ``Quant`` through every layer; here the analogue is
a tiny immutable wrapper over ``QuantConfig`` whose ``dot`` either runs the
plain fp matmul or the integer-domain one, keyed by the layer class the
call site declares.  Model code never branches on quantization itself —
it calls ``quant.dot(x, w, "mlp")`` unconditionally and the policy decides.
"""

from __future__ import annotations

import dataclasses
from typing import Optional

import jax

from .config import QuantConfig
from .quantize import int8_dot, int8_dot_batched


@dataclasses.dataclass(frozen=True)
class Quant:
    cfg: Optional[QuantConfig] = None

    def active(self, layer_class: str) -> bool:
        return self.cfg is not None and self.cfg.active_for(layer_class)

    @property
    def per_channel(self) -> bool:
        return self.cfg is not None and self.cfg.granularity == "per_channel"

    @property
    def quantized_kv(self) -> bool:
        return self.cfg is not None and self.cfg.kv_cache

    def dot(self, x: jax.Array, w: jax.Array, layer_class: str) -> jax.Array:
        """``x [..., d] @ w [d, f]``, int8 when the policy covers the class."""
        if not self.active(layer_class):
            return x @ w
        return int8_dot(x, w, per_channel=self.per_channel)

    def dot_batched(self, x: jax.Array, w: jax.Array, layer_class: str) -> jax.Array:
        """Expert-batched ``x [E, ..., d] @ w [E, d, f]`` (MoE matmuls)."""
        if not self.active(layer_class):
            return jax.numpy.einsum("e...d,edf->e...f", x, w)
        return int8_dot_batched(x, w, per_channel=self.per_channel)


def get_quant(cfg) -> Quant:
    """Policy for a ``ModelConfig`` (a no-op policy when quant is unset)."""
    return Quant(getattr(cfg, "quant", None))
