"""Learning-rate schedules (pure functions of the step counter)."""

from __future__ import annotations

import jax.numpy as jnp


def cosine_with_warmup(peak_lr: float, warmup_steps: int, total_steps: int,
                       final_frac: float = 0.1):
    def schedule(step):
        step = step.astype(jnp.float32)
        warm = peak_lr * step / max(warmup_steps, 1)
        progress = jnp.clip(
            (step - warmup_steps) / max(total_steps - warmup_steps, 1), 0.0, 1.0
        )
        cos = peak_lr * (final_frac + (1 - final_frac) * 0.5 * (1 + jnp.cos(jnp.pi * progress)))
        return jnp.where(step < warmup_steps, warm, cos)

    return schedule


def linear_warmup_constant(peak_lr: float, warmup_steps: int):
    def schedule(step):
        step = step.astype(jnp.float32)
        return peak_lr * jnp.minimum(1.0, step / max(warmup_steps, 1))

    return schedule
