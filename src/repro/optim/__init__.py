from .adamw import AdamW, Adafactor, make_optimizer  # noqa: F401
from .grad_compress import (  # noqa: F401
    compress_with_feedback,
    compressed_pmean,
    dequantize_int8,
    init_residual,
    quantize_int8,
)
from .schedules import cosine_with_warmup, linear_warmup_constant  # noqa: F401
