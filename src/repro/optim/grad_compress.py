"""Gradient compression for cross-pod data-parallel reduction.

At 512+ chips the inter-pod links are the scarcest bandwidth (DCN between
pods vs ICI within).  We compress the *cross-pod* gradient all-reduce to
int8 with per-tensor scales and error feedback (residual carried to the
next step), a standard large-scale trick that preserves convergence.

Usage inside a shard_map'd train step::

    g_pod = jax.lax.pmean(grads, axis_name="data")        # cheap intra-pod
    g, new_residual = compressed_pmean(g_pod, residual, axis_name="pod")

Outside shard_map (plain pjit), use ``quantize/dequantize`` around the
optimizer to emulate the same numerics (XLA then fuses the cast into the
all-reduce schedule it derives).
"""

from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp

# Per-tensor symmetric int8 (de)quantization lives in repro.quant now (the
# serving/training quantization subsystem); re-exported here because the
# compression path and its tests address them through this module.
from repro.quant.quantize import dequantize_int8, quantize_int8

__all__ = [
    "quantize_int8",
    "dequantize_int8",
    "compress_with_feedback",
    "compressed_pmean",
    "init_residual",
]


def compress_with_feedback(
    grads: Any, residual: Any
) -> tuple[Any, Any, Any]:
    """Quantize (grads + residual); return (q, scales, new_residual)."""

    def one(g, r):
        g32 = g.astype(jnp.float32) + r
        q, s = quantize_int8(g32)
        deq = dequantize_int8(q, s)
        return q, s, g32 - deq  # residual = quantization error

    out = jax.tree.map(one, grads, residual)
    is_triple = lambda x: isinstance(x, tuple) and len(x) == 3  # noqa: E731
    q = jax.tree.map(lambda o: o[0], out, is_leaf=is_triple)
    s = jax.tree.map(lambda o: o[1], out, is_leaf=is_triple)
    new_r = jax.tree.map(lambda o: o[2], out, is_leaf=is_triple)
    return q, s, new_r


def compressed_pmean(grads: Any, residual: Any, axis_name: str) -> tuple[Any, Any]:
    """int8 all-reduce with error feedback across ``axis_name``.

    The int8 payloads are summed in int32 (exact), then rescaled.  Scales are
    all-gathered (tiny).  Returns (averaged grads fp32, new residual).
    """
    q, s, new_r = compress_with_feedback(grads, residual)
    n = jax.lax.psum(1, axis_name)

    def reduce_one(qi, si):
        # Exact int32 sum of per-device int8 payloads, then average of
        # per-device dequantized values: sum_i q_i * s_i. With per-device
        # scales we need the weighted sum -> psum of dequantized bf16 would
        # lose the point, so all-gather scales and sum q_i*s_i via psum of
        # (q * s) in fp32 is equivalent; the wire benefit comes from XLA
        # sending int8 for the large payload when scales are uniform.
        # We implement the robust form: psum(q.astype(i32)) * mean-scale
        # correction requires uniform scales; instead psum fp32 of q*s:
        return jax.lax.psum(qi.astype(jnp.float32) * si, axis_name) / n

    avg = jax.tree.map(reduce_one, q, s)
    return avg, new_r


def init_residual(params: Any) -> Any:
    return jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
