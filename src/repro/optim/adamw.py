"""AdamW and Adafactor optimizers (pure pytree transforms, no optax).

AdamW keeps fp32 (m, v) and an fp32 master copy of the params when training
in bf16.  Adafactor factorizes the second moment for >= 2-D params — the
choice for the MoE giants (arctic-480b: fp32 AdamW state would need ~18
bytes/param; Adafactor needs ~4.1, see EXPERIMENTS.md memory table).
"""

from __future__ import annotations

import dataclasses
from typing import Any, Callable, NamedTuple

import jax
import jax.numpy as jnp


class AdamWState(NamedTuple):
    step: jax.Array
    m: Any
    v: Any


@dataclasses.dataclass(frozen=True)
class AdamW:
    lr: Callable[[jax.Array], jax.Array] | float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1

    def init(self, params) -> AdamWState:
        zeros = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
        return AdamWState(step=jnp.zeros((), jnp.int32), m=zeros, v=zeros)

    def _lr(self, step):
        return self.lr(step) if callable(self.lr) else self.lr

    def update(self, grads, state: AdamWState, params):
        step = state.step + 1
        lr = self._lr(step)
        b1, b2 = self.b1, self.b2

        def upd(g, m, v, p):
            g = g.astype(jnp.float32)
            m_new = b1 * m + (1 - b1) * g
            v_new = b2 * v + (1 - b2) * g * g
            m_hat = m_new / (1 - b1 ** step.astype(jnp.float32))
            v_hat = v_new / (1 - b2 ** step.astype(jnp.float32))
            delta = m_hat / (jnp.sqrt(v_hat) + self.eps)
            delta = delta + self.weight_decay * p.astype(jnp.float32)
            return (-lr * delta).astype(p.dtype), m_new, v_new

        out = jax.tree.map(upd, grads, state.m, state.v, params)
        updates = jax.tree.map(lambda o: o[0], out, is_leaf=lambda x: isinstance(x, tuple))
        m = jax.tree.map(lambda o: o[1], out, is_leaf=lambda x: isinstance(x, tuple))
        v = jax.tree.map(lambda o: o[2], out, is_leaf=lambda x: isinstance(x, tuple))
        new_params = jax.tree.map(lambda p, u: p + u, params, updates)
        return new_params, AdamWState(step=step, m=m, v=v)


class AdafactorState(NamedTuple):
    step: jax.Array
    # Per-leaf dicts: either {"r", "c"} (factored) or {"v"} (unfactored).
    stats: Any


@dataclasses.dataclass(frozen=True)
class Adafactor:
    lr: Callable[[jax.Array], jax.Array] | float = 1e-3
    decay: float = 0.8  # beta2_t = 1 - step^-decay
    eps: float = 1e-30
    clip_threshold: float = 1.0
    weight_decay: float = 0.0

    def init(self, params) -> AdafactorState:
        def stat(p):
            if p.ndim >= 2:
                return {
                    "r": jnp.zeros(p.shape[:-1], jnp.float32),
                    "c": jnp.zeros(p.shape[:-2] + p.shape[-1:], jnp.float32),
                }
            return {"v": jnp.zeros(p.shape, jnp.float32)}

        return AdafactorState(
            step=jnp.zeros((), jnp.int32),
            stats=jax.tree.map(stat, params),
        )

    def _lr(self, step):
        return self.lr(step) if callable(self.lr) else self.lr

    def update(self, grads, state: AdafactorState, params):
        step = state.step + 1
        t = step.astype(jnp.float32)
        beta2 = 1.0 - t ** (-self.decay)
        lr = self._lr(step)

        flat_g, treedef = jax.tree.flatten(grads)
        flat_p = treedef.flatten_up_to(params)
        flat_s = treedef.flatten_up_to(state.stats)

        new_p, new_s = [], []
        for g, p, s in zip(flat_g, flat_p, flat_s):
            g = g.astype(jnp.float32)
            g2 = g * g + self.eps
            if g.ndim >= 2:
                r = beta2 * s["r"] + (1 - beta2) * jnp.mean(g2, axis=-1)
                c = beta2 * s["c"] + (1 - beta2) * jnp.mean(g2, axis=-2)
                r_norm = r / jnp.maximum(
                    jnp.mean(r, axis=-1, keepdims=True), self.eps
                )
                v_hat = r_norm[..., None] * c[..., None, :]
                upd = g / jnp.sqrt(v_hat + self.eps)
                s_new = {"r": r, "c": c}
            else:
                v = beta2 * s["v"] + (1 - beta2) * g2
                upd = g / jnp.sqrt(v + self.eps)
                s_new = {"v": v}
            # Update clipping (Adafactor's RMS clip).
            rms = jnp.sqrt(jnp.mean(upd * upd) + self.eps)
            upd = upd / jnp.maximum(1.0, rms / self.clip_threshold)
            if self.weight_decay:
                upd = upd + self.weight_decay * p.astype(jnp.float32)
            new_p.append((p.astype(jnp.float32) - lr * upd).astype(p.dtype))
            new_s.append(s_new)

        return (
            jax.tree.unflatten(treedef, new_p),
            AdafactorState(step=step, stats=jax.tree.unflatten(treedef, new_s)),
        )


def make_optimizer(name: str, lr, **kw):
    if name == "adamw":
        return AdamW(lr=lr, **kw)
    if name == "adafactor":
        return Adafactor(lr=lr, **kw)
    raise ValueError(name)
