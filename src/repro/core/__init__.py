"""The paper's contribution: SystolicAttention / FSA in JAX.

Modules:
  pwl_exp2       — 8-segment piecewise-linear exp2 (paper §3.3, Fig. 12)
  attention      — Algorithm-1-faithful flash attention (exact or PWL exp2)
  systolic_model — cycle/utilization models reproducing Fig. 11
  fsa_sim        — instruction-level FSA device simulator (§4)
  fsa_kernel_api — NKI-style Python kernel programming model (§5)
  fsa_flash      — the paper's Listing 2 FlashAttention kernel
"""

from .attention import naive_attention, systolic_attention
from .pwl_exp2 import DEFAULT_SEGMENTS, pwl_exp2, pwl_exp, pwl_error_stats
from .systolic_model import (
    fsa_attention_cycles,
    fsa_tile_cycles,
    fsa_utilization,
    figure11,
)

__all__ = [
    "systolic_attention",
    "naive_attention",
    "pwl_exp2",
    "pwl_exp",
    "pwl_error_stats",
    "DEFAULT_SEGMENTS",
    "fsa_attention_cycles",
    "fsa_tile_cycles",
    "fsa_utilization",
    "figure11",
]
