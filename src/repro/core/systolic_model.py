"""Analytical cycle / utilization models of FSA and the commercial baselines.

Reproduces the paper's performance analysis:

  * §2.2 — a weight-stationary N x N array computing an N x M matmul takes
    ``M + 3N - 1`` cycles (preload N, synchronization 2N - 1);
  * §3.5 — one FSA FlashAttention inner iteration on an N x N tile takes
    ``2*N_COLS + 3*N_ROWS + 10 = 5N + 10`` cycles; the naive array needs up
    to ``8N - 2`` for the two matmuls alone; outer-loop rescale costs
    ``2N + 20`` per Q tile;
  * §8.2 — the single-direction (area-optimized) FSA variant: ``6N + 10``;
  * §6.1 / Fig. 11 — FLOPs/s utilization of FSA vs TPUv5e vs NeuronCore-v2
    for head_dim 128, seq 2048..16384 (FSA mean speedup 1.77x / 4.83x).

FSA utilization is *derived* (pure cycle counting).  The TPUv5e and
NeuronCore-v2 curves are hardware measurements in the paper; we model them
from first principles (matmul time vs softmax-on-vector-unit time with
software pipelining, plus array fill/drain and data-swap overheads) with the
vector-unit throughputs taken from public specs, and check that the resulting
mean speedups land near the paper's 1.77x / 4.83x.
"""

from __future__ import annotations

import dataclasses
import math

import numpy as np

__all__ = [
    "fsa_tile_cycles",
    "naive_tile_cycles",
    "fsa_attention_cycles",
    "fsa_utilization",
    "baseline_utilization",
    "figure11",
    "ACCELERATORS",
]

PAPER_SEQLENS = (2048, 4096, 6144, 8192, 10240, 12288, 14336, 16384)


def attention_flops(seq_len: int, head_dim: int) -> float:
    """Total FLOPs of one attention head forward (paper §6.1)."""
    return 4.0 * seq_len * seq_len * head_dim


# ---------------------------------------------------------------------------
# FSA (derived from the paper's cycle formulas)
# ---------------------------------------------------------------------------

def matmul_cycles(m: int, n: int) -> int:
    """N x N weight-stationary array, N x M moving matrix: M + 3N - 1 (§2.2)."""
    return m + 3 * n - 1


def fsa_tile_cycles(n: int, *, single_direction: bool = False) -> int:
    """Cycles per FlashAttention inner iteration on an N x N tile (§3.5, §8.2)."""
    return (6 * n + 10) if single_direction else (5 * n + 10)


def naive_tile_cycles(n: int) -> int:
    """Two dependent N x N matmuls on a naive array: 8N - 2 (§3.5)."""
    return 8 * n - 2


def fsa_rescale_cycles(n: int) -> int:
    """Per-outer-loop LSE normalization: 2N + 20 (§3.5)."""
    return 2 * n + 20


def fsa_attention_cycles(
    seq_len: int,
    head_dim: int = 128,
    array_n: int = 128,
    *,
    single_direction: bool = False,
) -> int:
    """Whole-head FlashAttention forward latency in cycles on FSA.

    Tiling per §3.5: Br = N_COLS, Bc = N_ROWS = d; so Tr = Tc = seq/N for
    d = N = 128.
    """
    assert head_dim == array_n, "FSA maps Bc = N_ROWS = d (paper §3.5)"
    tr = math.ceil(seq_len / array_n)
    tc = math.ceil(seq_len / array_n)
    inner = tr * tc * fsa_tile_cycles(array_n, single_direction=single_direction)
    outer = tr * fsa_rescale_cycles(array_n)
    return inner + outer


def fsa_utilization(
    seq_len: int,
    head_dim: int = 128,
    array_n: int = 128,
    *,
    single_direction: bool = False,
) -> float:
    """Matmul-FLOPs/s utilization of FSA: useful FLOPs / (cycles * 2N^2)."""
    cycles = fsa_attention_cycles(
        seq_len, head_dim, array_n, single_direction=single_direction
    )
    peak_flops_per_cycle = 2.0 * array_n * array_n
    return attention_flops(seq_len, head_dim) / (cycles * peak_flops_per_cycle)


# ---------------------------------------------------------------------------
# Commercial baselines (modelled; measured in the paper)
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class AcceleratorModel:
    """First-order model of FlashAttention on a systolic accelerator with an
    external vector/scalar unit (paper Table 1 + §2.3).

    The kernel software-pipelines matmul (on the array) against softmax (on
    the vector unit); per KV tile the achieved time is
    ``max(T_matmul, T_vector) + T_swap`` where ``T_swap`` covers the
    S/P round-trips (preload + sync + SRAM port contention, §2.3).
    """

    name: str
    array_n: int = 128
    num_arrays: int = 1
    freq_ghz: float = 1.5
    # Non-matmul fp ops per attention-score element (max/sub/exp/sum/scale
    # bookkeeping) executed on the vector+scalar units.
    vector_ops_per_elem: float = 6.0
    # Vector+scalar FLOPs per cycle (all lanes, whole chip).
    vector_flops_per_cycle: float = 512.0
    # Extra cycles per (Br x Bc) tile round-trip: preload + drain + sync +
    # port-contention stalls, in units of array_n (see §2.2-2.3).
    swap_overhead_tiles: float = 3.0
    block_q: int = 512
    block_k: int = 512

    @property
    def peak_matmul_flops_per_cycle(self) -> float:
        return 2.0 * self.array_n * self.array_n * self.num_arrays

    def utilization(self, seq_len: int, head_dim: int = 128) -> float:
        bq, bk = min(self.block_q, seq_len), min(self.block_k, seq_len)
        tr, tc = math.ceil(seq_len / bq), math.ceil(seq_len / bk)
        # Per inner tile: two matmuls of shapes (bq x d x bk) and (bq x bk x d)
        mm_flops = 2.0 * bq * bk * head_dim * 2
        t_mm = mm_flops / self.peak_matmul_flops_per_cycle + matmul_cycles(
            0, self.array_n
        )
        t_vec = (self.vector_ops_per_elem * bq * bk) / self.vector_flops_per_cycle
        t_swap = self.swap_overhead_tiles * self.array_n
        per_tile = max(t_mm, t_vec) + t_swap
        total_cycles = tr * tc * per_tile
        return attention_flops(seq_len, head_dim) / (
            total_cycles * self.peak_matmul_flops_per_cycle
        )


# Table 1 configs.  ``vector_flops_per_cycle`` is the *effective* non-matmul
# throughput, calibrated so the modelled mean utilization over the paper's
# seqlen sweep matches the paper's measured Fig. 11 means (FSA/TPUv5e = 1.77,
# FSA/Neuron-v2 = 4.83).  The calibrated values are far below the nominal
# lane counts — exactly the paper's point (§1-2): multi-cycle exp, fp32
# softmax, SRAM port contention and non-overlapped epilogues throttle the
# vector path.  Neuron's 31 ops/cycle effective is consistent with Fig. 1
# (the *scalar* engine, ~80% active, is the real bottleneck).
ACCELERATORS = {
    "tpu_v5e": AcceleratorModel(
        name="TPUv5e",
        num_arrays=4,
        freq_ghz=1.5,
        vector_flops_per_cycle=353.35,  # calibrated; nominal VPU is ~4096
        vector_ops_per_elem=6.0,
        swap_overhead_tiles=3.0,
        block_q=512,
        block_k=1024,
    ),
    "neuron_v2": AcceleratorModel(
        name="NeuronCore-v2",
        num_arrays=1,
        freq_ghz=2.8,
        vector_flops_per_cycle=31.27,  # calibrated; scalar-engine-bound
        vector_ops_per_elem=6.0,
        swap_overhead_tiles=3.0,
        block_q=128,
        block_k=2048,
    ),
}


def baseline_utilization(which: str, seq_len: int, head_dim: int = 128) -> float:
    return ACCELERATORS[which].utilization(seq_len, head_dim)


def figure11(head_dim: int = 128, seqlens=PAPER_SEQLENS) -> dict:
    """Reproduce Fig. 11: utilization curves + mean speedups (1.77x, 4.83x)."""
    rows = []
    for s in seqlens:
        fsa = fsa_utilization(s, head_dim)
        tpu = baseline_utilization("tpu_v5e", s, head_dim)
        neuron = baseline_utilization("neuron_v2", s, head_dim)
        rows.append(
            {
                "seq_len": s,
                "fsa": fsa,
                "fsa_single_dir": fsa_utilization(s, head_dim, single_direction=True),
                "tpu_v5e": tpu,
                "neuron_v2": neuron,
            }
        )
    mean = lambda k: float(np.mean([r[k] for r in rows]))  # noqa: E731
    return {
        "rows": rows,
        "mean_fsa": mean("fsa"),
        "mean_tpu_v5e": mean("tpu_v5e"),
        "mean_neuron_v2": mean("neuron_v2"),
        "speedup_vs_tpu_v5e": mean("fsa") / mean("tpu_v5e"),
        "speedup_vs_neuron_v2": mean("fsa") / mean("neuron_v2"),
        "paper_speedup_vs_tpu_v5e": 1.77,
        "paper_speedup_vs_neuron_v2": 4.83,
    }
