"""Functional instruction-level simulator of the FSA device (paper §4).

Models the FSA microarchitecture at the fidelity needed to validate the
SystolicAttention schedule and its numerics:

  * three memory spaces with the paper's Table 1 capacities enforced —
    main memory (unbounded), scratchpad SRAM (192 KiB), accumulation SRAM
    (64 KiB);
  * the five compute instructions of §4.2 (LoadStationary, AttnScore,
    AttnValue, Reciprocal, AttnLseNorm) plus Load/Store DMA;
  * FSA numerics: fp16 operands, fp32 accumulation, rowmax via the CMP row,
    exp2 via the 8-segment PWL interpolation (Split unit + MAC);
  * deterministic cycle accounting per §3.5: the dual-FSM controller
    overlaps consecutive compute instructions so one inner FlashAttention
    iteration (LoadStationary + AttnScore + AttnValue) advances the
    timeline by exactly ``5N + 10`` cycles, and the outer-loop epilogue
    (Reciprocal + AttnLseNorm) by ``2N + 20``.

The simulator is intentionally *functional*: matrices move as whole tiles,
not element wavefronts, but every arithmetic result matches what the RTL
produces (same op order, same fp32 accumulate, same PWL tables), and every
latency matches the paper's closed-form cycle counts.
"""

from __future__ import annotations

import dataclasses
from typing import Optional

import numpy as np

from .pwl_exp2 import LOG2_E, segment_table

__all__ = ["FSADevice", "FSAProgram", "Instr"]


def _pwl_exp2_np(x: np.ndarray, num_segments: int = 8) -> np.ndarray:
    """NumPy twin of core.pwl_exp2.pwl_exp2 (fp32, FTZ) for the simulator."""
    slope, intercept = segment_table(num_segments)
    x = x.astype(np.float32)
    x_i = np.ceil(x)
    x_f = x - x_i
    idx = np.clip(np.floor((x_f + 1.0) * num_segments).astype(np.int32), 0, num_segments - 1)
    frac = slope[idx] * x_f + intercept[idx]
    e = np.clip(x_i, -150, 127).astype(np.int32)
    out = np.ldexp(frac, e)
    out[x_i < -148] = 0.0
    return out.astype(np.float32)


@dataclasses.dataclass
class Instr:
    op: str
    operands: dict

    def __repr__(self) -> str:  # compact program listings
        return f"{self.op}({', '.join(f'{k}={v}' for k, v in self.operands.items())})"


@dataclasses.dataclass
class FSAProgram:
    instrs: list[Instr] = dataclasses.field(default_factory=list)

    def emit(self, op: str, **operands) -> None:
        self.instrs.append(Instr(op, operands))


class FSADevice:
    """Executes an FSAProgram; tracks memory capacity and cycle time."""

    def __init__(
        self,
        array_n: int = 128,
        spad_bytes: int = 192 * 1024,
        accum_bytes: int = 64 * 1024,
        num_segments: int = 8,
        freq_ghz: float = 1.5,
        single_direction: bool = False,
    ):
        self.n = array_n
        self.spad_bytes = spad_bytes
        self.accum_bytes = accum_bytes
        self.num_segments = num_segments
        self.freq_ghz = freq_ghz
        self.single_direction = single_direction
        self.reset()

    def reset(self) -> None:
        self.main: dict[str, np.ndarray] = {}
        self.spad: dict[str, np.ndarray] = {}
        self.accum: dict[str, np.ndarray] = {}
        self.stationary: Optional[np.ndarray] = None  # [d, Br] fp16
        self.old_m: Optional[np.ndarray] = None  # CMP-row registers, fp32
        self.cycles = 0
        self.compute_cycles = 0
        self.instr_count = 0

    # -- memory management ---------------------------------------------------

    def _check_capacity(self, space: dict, limit: int, name: str) -> None:
        used = sum(a.nbytes for a in space.values())
        if used > limit:
            raise MemoryError(
                f"{name} over capacity: {used} bytes used, limit {limit} "
                f"(tiles: { {k: v.shape for k, v in space.items()} })"
            )

    def alloc(self, space: str, key: str, shape: tuple, dtype) -> None:
        target = {"main": self.main, "spad": self.spad, "accum": self.accum}[space]
        target[key] = np.zeros(shape, dtype=dtype)
        if space == "spad":
            self._check_capacity(self.spad, self.spad_bytes, "scratchpad SRAM")
        elif space == "accum":
            self._check_capacity(self.accum, self.accum_bytes, "accumulation SRAM")

    # -- execution -----------------------------------------------------------

    def stagger_cycles(self, op: str) -> int:
        """Cycles the timeline advances when ``op`` issues behind its
        predecessor on the dual-FSM controller (§4.3)."""
        stagger = _COMPUTE_STAGGER[op](self.n)
        if self.single_direction and op == "attn_score":
            # §8.2 area-optimized variant: no upward-path registers, so S
            # drains through the bottom and the score pass cannot overlap
            # the preceding preload — one inner iteration becomes 6N + 10
            # instead of 5N + 10.
            stagger += self.n
        return stagger

    def run(self, program: FSAProgram) -> None:
        prev_compute = None
        for ins in program.instrs:
            self.instr_count += 1
            handler = getattr(self, f"_op_{ins.op}")
            handler(**ins.operands)
            if ins.op in _COMPUTE_STAGGER:
                # Dual-FSM controller (§4.3): the next compute instruction is
                # issued as soon as its data dependency inside the array is
                # met, so the timeline advances by the *stagger* of each
                # instruction, not its full latency.
                self.compute_cycles += self.stagger_cycles(ins.op)
                prev_compute = ins.op
        # Drain the last instruction's tail through the array.
        if prev_compute is not None:
            self.compute_cycles += _DRAIN_TAIL(self.n)
        self.cycles = self.compute_cycles

    # -- DMA -----------------------------------------------------------------

    def _op_load_tile(self, src: str, dst: str) -> None:
        self.spad[dst] = self.main[src].astype(np.float16)
        self._check_capacity(self.spad, self.spad_bytes, "scratchpad SRAM")

    def _op_store_tile(self, src: str, dst: str) -> None:
        self.main[dst] = self.accum[src].copy()

    # -- compute (§4.2) --------------------------------------------------------

    def _op_load_stationary(
        self, tile: str, transpose: bool = False, reset_stats: bool = True
    ) -> None:
        t = self.spad[tile].astype(np.float16)
        self.stationary = t.T if transpose else t  # [d, Br] layout
        if reset_stats:
            # Fresh Q tile -> reset the CMP-row running max.  Listing 2
            # reloads the same Q every inner iteration (the array held P/V
            # meanwhile); those reloads must NOT clear the running max.
            self.old_m = np.full((self.stationary.shape[1],), -np.inf, np.float32)

    def _op_attn_score(self, k: str, l: str, scale: float) -> None:
        """QK^T fused with online softmax: leaves P resident in the array.

        Implements lines 6-14 of Algorithm 1 with FSA semantics: rowmax via
        the CMP row as S streams out of the top, subtraction + constant
        multiply + PWL exp2 in place, rowsum on the way down.  ``l`` is the
        accumulation-SRAM tile holding (old_l) and receives new_l; the
        rescale factor b is forwarded down to the accumulator where it also
        rescales the O accumulator (handled in _op_attn_value via saved b).
        """
        assert self.stationary is not None, "load_stationary must precede attn_score"
        q = self.stationary.astype(np.float32)  # [d, Br]
        kt = self.spad[k].astype(np.float32)  # [Bc, d]
        # fp16 MACs with fp32 accumulation (Table 1), but S leaves the array
        # through the top as a 16-bit activation — quantize it.
        s = (kt @ q).astype(np.float16)  # [Bc, Br]: rows of S = cols of array
        c = np.float16(scale * LOG2_E)

        local_m = s.max(axis=0)  # CMP row: per-column (= per-Q-row) max
        new_m = np.maximum(local_m, self.old_m.astype(np.float16))
        a = np.maximum(
            (self.old_m.astype(np.float16) - new_m).astype(np.float32), -1e4
        )
        b = _pwl_exp2_np(np.float32(c) * a, self.num_segments)
        # N = S - new_m and the constant multiply happen on fp16 values
        # resident in the PEs; the PWL MAC accumulates in fp32, and P is
        # held back in the PE registers as fp16 (it feeds fp16 MACs in PV).
        n_mat = (s - new_m[None, :]).astype(np.float16)
        arg = (c * n_mat).astype(np.float32)
        p = _pwl_exp2_np(arg, self.num_segments).astype(np.float16)
        local_l = p.astype(np.float32).sum(axis=0)

        old_l = self.accum[l].reshape(-1)
        self.accum[l] = (old_l * b + local_l).reshape(self.accum[l].shape)
        self.old_m = new_m.astype(np.float32)
        self._p = p  # resident stationary (fp16) for AttnValue
        self._b = b

    def _op_attn_value(self, v: str, o: str) -> None:
        """O accumulation: local_O = P V along the downward path (line 15-16)."""
        vt = self.spad[v].astype(np.float32)  # [d, Bc] (V pre-transposed)
        p = self._p.astype(np.float32)  # [Bc, Br]
        local_o = vt @ p  # [d, Br]
        self.accum[o] = (self.accum[o] * self._b[None, :] + local_o).astype(np.float32)

    def _op_reciprocal(self, l: str) -> None:
        vals = self.accum[l]
        self._recip = np.where(vals == 0, 0.0, 1.0 / vals).astype(np.float32)

    def _op_attn_lse_norm(self, o: str) -> None:
        self.accum[o] = (self.accum[o] * self._recip.reshape(1, -1)).astype(np.float32)

    # -- reporting -------------------------------------------------------------

    def seconds(self) -> float:
        return self.cycles / (self.freq_ghz * 1e9)


# Stagger (cycles the timeline advances when this instruction issues behind
# its predecessor on the dual-FSM controller) chosen so that one inner
# iteration = 5N + 10 and the outer epilogue = 2N + 20, matching §3.5.
_COMPUTE_STAGGER = {
    "load_stationary": lambda n: n,          # preload, overlapped drain
    "attn_score": lambda n: 2 * n + 10,      # up-pass + CMP + in-place elementwise
    "attn_value": lambda n: 2 * n,           # down-pass PV
    "reciprocal": lambda n: 10,              # accumulator-local
    "attn_lse_norm": lambda n: 2 * n + 10,   # read-modify-write of O tile
}
_DRAIN_TAIL = lambda n: 0  # noqa: E731  (tail folded into staggers)
