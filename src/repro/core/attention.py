"""SystolicAttention — the paper's Algorithm 1 as a pure-JAX function.

This is the *paper-faithful* reference implementation of the technique:
FlashAttention-2/3 forward with

  * the exact floating-point operation order of Algorithm 1 (rowmax on the
    **unscaled** scores, 1/sqrt(d) folded into the exp2 argument),
  * exp implemented as ``exp2(log2(e)/sqrt(d) * x)``,
  * optionally the FSA 8-segment piecewise-linear exp2 (paper §3.3),
  * fp32 accumulation regardless of input dtype (FlashAttention-2/3 and the
    FSA accumulator both accumulate in fp32).

It is written with `jax.lax.scan` over key/value tiles so it lowers to clean
HLO on any backend — this is also the implementation used by the multi-pod
dry-run cells (Pallas does not lower on the CPU host platform; see
DESIGN.md §6).  The Pallas TPU kernel in ``repro.kernels.flash_attention``
implements the same schedule with explicit VMEM BlockSpecs and is validated
against this function and the naive oracle.
"""

from __future__ import annotations

import functools
from typing import Callable, Optional

import jax
import jax.numpy as jnp
import numpy as np

from .pwl_exp2 import DEFAULT_SEGMENTS, LOG2_E, pwl_exp2

__all__ = ["systolic_attention", "naive_attention"]

NEG_INF = -1e30  # finite stand-in for -inf; keeps PWL split well-defined


def _exp2_fn(impl: str, num_segments: int) -> Callable[[jax.Array], jax.Array]:
    if impl == "exact":
        return jnp.exp2
    if impl == "pwl":
        return functools.partial(pwl_exp2, num_segments=num_segments)
    raise ValueError(f"unknown exp2 impl: {impl!r} (want 'exact' or 'pwl')")


def _attend_single(
    q: jax.Array,  # [Sq, d]
    k: jax.Array,  # [Sk, d]
    v: jax.Array,  # [Sk, dv]
    *,
    causal: bool,
    block_q: int,
    block_k: int,
    exp2: Callable,
    scale: float,
    q_offset: int | jax.Array = 0,
    bias: Optional[jax.Array] = None,  # [Sq, Sk]
    unroll: bool = False,
) -> jax.Array:
    """One (batch, head) slice of Algorithm 1.  fp32 state, tiled KV scan."""
    sq, d = q.shape
    sk, dv = v.shape[0], v.shape[1]
    n_q = -(-sq // block_q)
    n_k = -(-sk // block_k)

    c = scale * LOG2_E  # log2(e)/sqrt(d): folded into the exp2 argument

    q32 = q.astype(jnp.float32)
    k32 = k.astype(jnp.float32)
    v32 = v.astype(jnp.float32)
    # Pad ragged edges up to whole tiles; padded *keys* are masked below.
    pad_q, pad_k = n_q * block_q - sq, n_k * block_k - sk
    if pad_q:
        q32 = jnp.pad(q32, ((0, pad_q), (0, 0)))
    if pad_k:
        k32 = jnp.pad(k32, ((0, pad_k), (0, 0)))
        v32 = jnp.pad(v32, ((0, pad_k), (0, 0)))
    if bias is not None and (pad_q or pad_k):
        bias = jnp.pad(bias, ((0, pad_q), (0, pad_k)))

    def outer(_, i):
        q_i = jax.lax.dynamic_slice_in_dim(q32, i * block_q, block_q, axis=0)

        def inner(carry, j):
            old_m, old_l, old_o = carry
            k_j = jax.lax.dynamic_slice_in_dim(k32, j * block_k, block_k, axis=0)
            v_j = jax.lax.dynamic_slice_in_dim(v32, j * block_k, block_k, axis=0)

            # line 6: S = Q_i K_j^T  (unscaled, as in Algorithm 1)
            s = q_i @ k_j.T  # [Bq, Bk]

            if bias is not None:
                b_ij = jax.lax.dynamic_slice(
                    bias, (i * block_q, j * block_k), (block_q, block_k)
                ).astype(jnp.float32)
                s = s + b_ij / scale  # bias enters pre-scale score space
            # Masks enter as an additive [Bq, Bk] bias shared across
            # batch/heads (a pred broadcast to [B, H, Bq, Bk] gets hoisted
            # out of the layer loop as a multi-GiB constant).
            cols = j * block_k + jnp.arange(block_k)[None, :]
            if pad_k:
                s = s + jnp.where(cols < sk, 0.0, NEG_INF)
            if causal:
                rows = i * block_q + q_offset + jnp.arange(block_q)[:, None]
                s = s + jnp.where(rows >= cols, 0.0, NEG_INF)

            # lines 7-9: rowmax, running max, a = old_m - new_m
            local_m = jnp.max(s, axis=-1)
            new_m = jnp.maximum(local_m, old_m)
            a = old_m - new_m
            # line 10: b = exp2(log2e/sqrt(d) * a)
            b = exp2(c * a)
            # lines 11-12: N = S - new_m ; P = exp2(log2e/sqrt(d) * N)
            n = s - new_m[:, None]
            p = exp2(c * n)
            # lines 13-16
            local_l = jnp.sum(p, axis=-1)
            new_l = old_l * b + local_l
            local_o = p @ v_j
            new_o = b[:, None] * old_o + local_o
            return (new_m, new_l, new_o), None

        init = (
            jnp.full((block_q,), NEG_INF, jnp.float32),
            jnp.zeros((block_q,), jnp.float32),
            jnp.zeros((block_q, dv), jnp.float32),
        )
        (m, l, o), _ = jax.lax.scan(
            inner, init, jnp.arange(n_k), unroll=n_k if unroll else 1
        )
        # line 21: O_i = diag(l)^-1 O   (guard fully-masked rows)
        safe_l = jnp.where(l == 0.0, 1.0, l)
        return (), o / safe_l[:, None]

    _, o_blocks = jax.lax.scan(
        outer, (), jnp.arange(n_q), unroll=n_q if unroll else 1
    )
    return o_blocks.reshape(n_q * block_q, dv)[:sq]


def systolic_attention(
    q: jax.Array,  # [B, Sq, H, d]
    k: jax.Array,  # [B, Sk, Hkv, d]
    v: jax.Array,  # [B, Sk, Hkv, dv]
    *,
    causal: bool = False,
    block_q: int = 128,
    block_k: int = 128,
    exp2_impl: str = "exact",
    num_segments: int = DEFAULT_SEGMENTS,
    scale: Optional[float] = None,
    q_offset: int | jax.Array = 0,
    bias: Optional[jax.Array] = None,
    unroll: bool = False,
) -> jax.Array:
    """Batched multi-head SystolicAttention (GQA-aware).

    Args:
      q/k/v: [batch, seq, heads, head_dim]; kv heads may be a divisor of q
        heads (GQA — kv heads are repeated logically, not materialized
        per-q-head in HBM; the repeat happens on the fly).
      exp2_impl: "exact" (native exp2; the fast mode) or "pwl" (the paper's
        8-segment interpolation; numerics-faithful mode).
      q_offset: absolute position of q[0] (for decode/chunked prefill
        causal masking against a longer KV).
      bias: optional additive attention bias broadcastable to [Sq, Sk].
    """
    b_, sq, h, d = q.shape
    hkv = k.shape[2]
    assert h % hkv == 0, (h, hkv)
    rep = h // hkv
    if scale is None:
        scale = 1.0 / float(np.sqrt(d))
    exp2 = _exp2_fn(exp2_impl, num_segments)

    bq = min(block_q, sq)
    bk = min(block_k, k.shape[1])

    fn = functools.partial(
        _attend_single,
        causal=causal,
        block_q=bq,
        block_k=bk,
        exp2=exp2,
        scale=scale,
        q_offset=q_offset,
        bias=bias,
        unroll=unroll,
    )
    # GQA without materializing repeated KV: vmap q's rep dim with KV
    # broadcast (in_axes=None), then over kv-heads, then batch.
    fn = jax.vmap(fn, in_axes=(0, None, None))  # rep (q heads per kv head)
    fn = jax.vmap(fn, in_axes=(0, 0, 0))        # kv heads
    fn = jax.vmap(fn, in_axes=(0, 0, 0))        # batch
    qg = jnp.transpose(q, (0, 2, 1, 3)).reshape(b_, hkv, rep, sq, d)
    out = fn(
        qg,
        jnp.transpose(k, (0, 2, 1, 3)),
        jnp.transpose(v, (0, 2, 1, 3)),
    )  # [B, Hkv, rep, Sq, dv]
    out = out.reshape(b_, h, sq, v.shape[-1])
    return jnp.transpose(out, (0, 2, 1, 3)).astype(q.dtype)


def naive_attention(
    q: jax.Array,
    k: jax.Array,
    v: jax.Array,
    *,
    causal: bool = False,
    scale: Optional[float] = None,
    q_offset: int | jax.Array = 0,
    bias: Optional[jax.Array] = None,
    dtype: jnp.dtype = jnp.float32,
) -> jax.Array:
    """Materialized-softmax oracle (the ref implementation for all kernels)."""
    b_, sq, h, d = q.shape
    hkv = k.shape[2]
    rep = h // hkv
    if scale is None:
        scale = 1.0 / float(np.sqrt(d))
    kr = jnp.repeat(k, rep, axis=2) if rep > 1 else k
    vr = jnp.repeat(v, rep, axis=2) if rep > 1 else v
    s = jnp.einsum("bqhd,bkhd->bhqk", q.astype(dtype), kr.astype(dtype)) * scale
    if bias is not None:
        s = s + bias.astype(dtype)
    if causal:
        rows = q_offset + jnp.arange(sq)[:, None]
        cols = jnp.arange(k.shape[1])[None, :]
        s = jnp.where(rows >= cols, s, -jnp.inf)
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bhqk,bkhd->bqhd", p, vr.astype(dtype))
    return o.astype(q.dtype)
