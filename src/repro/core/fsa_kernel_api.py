"""FSA kernel programming model (paper §5) — the NKI-inspired Python API.

Faithful to the paper's Listing 1/2 surface:

  * three type-safe tensor classes scoped to a memory space — ``MTile``
    (main memory), ``STile`` (scratchpad SRAM), ``ATile`` (accumulation
    SRAM) — supporting ``shape``, ``dtype``, ``split`` and ``to_numpy``;
  * one Python function per FSA instruction (``load_tile``,
    ``store_tile``, ``load_stationary``, ``attn_score``, ``attn_value``,
    ``reciprocal``, ``attn_lse_norm``);
  * an ``@kernel`` decorator that JIT-packages the traced instruction
    stream into an ``FSAProgram`` and executes it on the ``FSADevice``
    simulator (the paper targets a Verilator RTL simulation; our device
    model reproduces its arithmetic and cycle counts — see fsa_sim.py).

``examples/fsa_kernel_demo.py`` reproduces the paper's Listing 2
FlashAttention kernel on top of this API.
"""

from __future__ import annotations

import dataclasses
import functools
import threading
from typing import Callable, Optional

import numpy as np

from .fsa_sim import FSADevice, FSAProgram

__all__ = [
    "MTile", "STile", "ATile",
    "alloc_mem", "alloc_spad", "alloc_accum",
    "load_tile", "store_tile", "load_stationary",
    "attn_score", "attn_value", "reciprocal", "attn_lse_norm",
    "kernel", "KernelResult",
]

_state = threading.local()


def _ctx() -> "_KernelContext":
    ctx = getattr(_state, "ctx", None)
    if ctx is None:
        raise RuntimeError("FSA instructions must run inside an @fsa.kernel function")
    return ctx


class _KernelContext:
    def __init__(self, device: FSADevice):
        self.device = device
        self.program = FSAProgram()
        self.counter = 0

    def fresh(self, prefix: str) -> str:
        self.counter += 1
        return f"{prefix}_{self.counter}"

    def emit(self, op: str, **operands) -> None:
        """Record the instruction and execute it eagerly on the device."""
        self.program.emit(op, **operands)


@dataclasses.dataclass
class _Tile:
    key: str
    shape: tuple
    dtype: np.dtype
    space: str

    def split(self, size: int, dim: int = -1) -> list:
        """Tile views along one dimension (paper Listing 2 usage)."""
        dim = dim % len(self.shape)
        n = self.shape[dim]
        assert n % size == 0, (n, size)
        out = []
        for i in range(n // size):
            sub = dataclasses.replace(
                self,
                key=f"{self.key}[{dim}:{i*size}:{(i+1)*size}]",
                shape=tuple(size if d == dim else s for d, s in enumerate(self.shape)),
            )
            sub._parent = self  # type: ignore[attr-defined]
            sub._slice = (dim, i * size, (i + 1) * size)  # type: ignore[attr-defined]
            out.append(sub)
        return out

    # view plumbing -----------------------------------------------------------
    _parent: Optional["_Tile"] = dataclasses.field(default=None, repr=False)
    _slice: Optional[tuple] = dataclasses.field(default=None, repr=False)

    def _read(self, mem: dict) -> np.ndarray:
        if self._parent is None:
            return mem[self.key]
        base = self._parent._read(mem)
        dim, lo, hi = self._slice
        idx = tuple(slice(lo, hi) if d == dim else slice(None) for d in range(base.ndim))
        return base[idx]

    def _write(self, mem: dict, value: np.ndarray) -> None:
        if self._parent is None:
            mem[self.key] = value
            return
        base = self._parent._read(mem)
        dim, lo, hi = self._slice
        idx = tuple(slice(lo, hi) if d == dim else slice(None) for d in range(base.ndim))
        base[idx] = value


class MTile(_Tile):
    def to_numpy(self) -> np.ndarray:
        return np.asarray(self._read(_ctx().device.main))


class STile(_Tile):
    pass


class ATile(_Tile):
    pass


# -- allocation ----------------------------------------------------------------

def alloc_mem(shape, dtype=np.float16, data: Optional[np.ndarray] = None, name=None) -> MTile:
    ctx = _ctx()
    key = name or ctx.fresh("m")
    ctx.device.alloc("main", key, tuple(shape), dtype)
    if data is not None:
        assert tuple(data.shape) == tuple(shape), (data.shape, shape)
        ctx.device.main[key] = np.asarray(data, dtype=dtype)
    return MTile(key, tuple(shape), np.dtype(dtype), "main")


def alloc_spad(shape, dtype=np.float16, name=None) -> STile:
    ctx = _ctx()
    key = name or ctx.fresh("s")
    ctx.device.alloc("spad", key, tuple(shape), dtype)
    return STile(key, tuple(shape), np.dtype(dtype), "spad")


def alloc_accum(shape, dtype=np.float32, name=None) -> ATile:
    ctx = _ctx()
    key = name or ctx.fresh("a")
    ctx.device.alloc("accum", key, tuple(shape), dtype)
    return ATile(key, tuple(shape), np.dtype(dtype), "accum")


# -- DMA instructions -----------------------------------------------------------

def load_tile(src: MTile, dst: STile) -> None:
    assert isinstance(src, MTile) and isinstance(dst, STile), "load_tile: MTile -> STile"
    ctx = _ctx()
    ctx.emit("load_tile", src=src.key, dst=dst.key)
    dst._write(ctx.device.spad, src._read(ctx.device.main).astype(np.float16))


def store_tile(src: ATile, dst: MTile) -> None:
    assert isinstance(src, ATile) and isinstance(dst, MTile), "store_tile: ATile -> MTile"
    ctx = _ctx()
    ctx.emit("store_tile", src=src.key, dst=dst.key)
    dst._write(ctx.device.main, src._read(ctx.device.accum).astype(dst.dtype))


# -- compute instructions ---------------------------------------------------------

def _advance(op: str) -> None:
    dev = _ctx().device
    dev.compute_cycles += dev.stagger_cycles(op)
    dev.cycles = dev.compute_cycles
    dev.instr_count += 1


def load_stationary(tile: STile, transpose: bool = False, reset_stats: bool = True) -> None:
    assert isinstance(tile, STile)
    ctx = _ctx()
    ctx.emit("load_stationary", tile=tile.key, transpose=transpose, reset_stats=reset_stats)
    t = tile._read(ctx.device.spad).astype(np.float16)
    ctx.device.stationary = t.T if transpose else t
    if reset_stats:
        ctx.device.old_m = np.full(
            (ctx.device.stationary.shape[1],), -np.inf, np.float32
        )
    _advance("load_stationary")


def attn_score(k: STile, l: ATile, scale: float) -> None:
    assert isinstance(k, STile) and isinstance(l, ATile)
    ctx = _ctx()
    ctx.emit("attn_score", k=k.key, l=l.key, scale=scale)
    dev = ctx.device
    # Route through the device op on materialized views.
    dev.spad["__k"] = k._read(dev.spad)
    dev.accum["__l"] = l._read(dev.accum)
    dev._op_attn_score(k="__k", l="__l", scale=scale)
    l._write(dev.accum, dev.accum.pop("__l"))
    dev.spad.pop("__k")
    _advance("attn_score")


def attn_value(v: STile, o: ATile) -> None:
    assert isinstance(v, STile) and isinstance(o, ATile)
    ctx = _ctx()
    ctx.emit("attn_value", v=v.key, o=o.key)
    dev = ctx.device
    dev.spad["__v"] = v._read(dev.spad)
    dev.accum["__o"] = o._read(dev.accum)
    dev._op_attn_value(v="__v", o="__o")
    o._write(dev.accum, dev.accum.pop("__o"))
    dev.spad.pop("__v")
    _advance("attn_value")


def reciprocal(l: ATile) -> None:
    assert isinstance(l, ATile)
    ctx = _ctx()
    ctx.emit("reciprocal", l=l.key)
    dev = ctx.device
    dev.accum["__l"] = l._read(dev.accum)
    dev._op_reciprocal(l="__l")
    dev.accum.pop("__l")
    _advance("reciprocal")


def attn_lse_norm(o: ATile) -> None:
    assert isinstance(o, ATile)
    ctx = _ctx()
    ctx.emit("attn_lse_norm", o=o.key)
    dev = ctx.device
    dev.accum["__o"] = o._read(dev.accum)
    dev._op_attn_lse_norm(o="__o")
    o._write(dev.accum, dev.accum.pop("__o"))
    _advance("attn_lse_norm")


# -- the JIT decorator -------------------------------------------------------------

@dataclasses.dataclass
class KernelResult:
    output: np.ndarray
    cycles: int
    instr_count: int
    program: FSAProgram
    device: FSADevice

    def seconds(self) -> float:
        return self.device.seconds()


def kernel(device: str = "fsa_sim", array_n: int = 128, **dev_kwargs) -> Callable:
    """Compile+run a Python FSA kernel on the device simulator.

    The decorated function receives/returns tiles; numpy array arguments are
    auto-wrapped as MTiles.  Returns a KernelResult with the output array,
    the instruction program and the cycle count.
    """

    def deco(fn: Callable) -> Callable:
        @functools.wraps(fn)
        def wrapper(*arrays: np.ndarray) -> KernelResult:
            dev = FSADevice(array_n=array_n, **dev_kwargs)
            ctx = _KernelContext(dev)
            _state.ctx = ctx
            try:
                tiles = [
                    alloc_mem(a.shape, np.float16, data=np.asarray(a)) for a in arrays
                ]
                out = fn(*tiles)
                result = out.to_numpy() if isinstance(out, MTile) else out
            finally:
                _state.ctx = None
            return KernelResult(
                output=result,
                cycles=dev.cycles,
                instr_count=dev.instr_count,
                program=ctx.program,
                device=dev,
            )

        return wrapper

    return deco
