"""The paper's Listing 2: FlashAttention as an FSA kernel.

Single-head FlashAttention forward on the FSA device simulator using the
§5 Python programming model, with the exact tile/loop structure of the
paper's open-source kernel: Q stationary per inner iteration, K streamed,
V pre-transposed, double-buffered scratchpad tiles, log-expsum and O
accumulated in accumulation SRAM, LSE-normalized once per outer iteration.
"""

from __future__ import annotations

import numpy as np

from . import fsa_kernel_api as F

__all__ = ["fsa_flash_attention"]


def fsa_flash_attention(
    q: np.ndarray,  # [LEN, d]
    k: np.ndarray,  # [LEN, d]
    v: np.ndarray,  # [LEN, d]
    *,
    array_n: int = 128,
    num_segments: int = 8,
    spad_bytes: int = 192 * 1024,
    accum_bytes: int | None = None,
    single_direction: bool = False,
) -> F.KernelResult:
    """Run one attention head through the FSA simulator; returns KernelResult.

    Tiling per §3.5: Br = N_COLS, Bc = N_ROWS = d = array_n.
    """
    seq, d = q.shape
    assert d == array_n, f"FSA maps Bc = N_ROWS = d (= {array_n}); got d={d}"
    assert seq % array_n == 0, (seq, array_n)
    br = bc = array_n
    scale = 1.0 / float(np.sqrt(d))
    vt = np.ascontiguousarray(v.T)  # host-side pre-transpose (paper §5.3)

    # The paper's 64 KiB accumulation SRAM holds one O tile + one l tile
    # (128*128*4 + 128*4 bytes); size it exactly unless overridden.
    if accum_bytes is None:
        accum_bytes = d * br * 4 + br * 4

    @F.kernel(array_n=array_n, num_segments=num_segments,
              spad_bytes=spad_bytes, accum_bytes=accum_bytes,
              single_direction=single_direction)
    def attention(Q: F.MTile, K: F.MTile, Vt: F.MTile) -> F.MTile:
        Ot = F.alloc_mem((d, seq), np.float32, name="Ot")
        Ot_tiles = Ot.split(br, dim=-1)     # [d, br]
        Q_tiles = Q.split(br, dim=-2)       # [br, d]
        K_tiles = K.split(bc, dim=-2)       # [bc, d]
        Vt_tiles = Vt.split(bc, dim=-1)     # [d, bc]

        # double buffering for Q, K, Vt (paper Listing 2)
        Q_spad = (F.alloc_spad((br, d)), F.alloc_spad((br, d)))
        K_spad = (F.alloc_spad((bc, d)), F.alloc_spad((bc, d)))
        Vt_spad = (F.alloc_spad((d, bc)), F.alloc_spad((d, bc)))

        log_expsum = F.alloc_accum((1, br))
        Ot_accum = F.alloc_accum((d, br))

        for i, Q_i in enumerate(Q_tiles):
            F.load_tile(Q_i, Q_spad[i % 2])
            # reset accumulators for this Q tile
            _zero(log_expsum)
            _zero(Ot_accum)
            for j, (K_j, Vt_j) in enumerate(zip(K_tiles, Vt_tiles)):
                F.load_stationary(Q_spad[i % 2], transpose=True, reset_stats=(j == 0))
                F.load_tile(K_j, K_spad[j % 2])
                F.attn_score(K_spad[j % 2], log_expsum, scale=scale)
                F.load_tile(Vt_j, Vt_spad[j % 2])
                F.attn_value(Vt_spad[j % 2], Ot_accum)
            F.reciprocal(log_expsum)
            F.attn_lse_norm(Ot_accum)
            F.store_tile(Ot_accum, Ot_tiles[i])
        return Ot

    def _zero(tile):
        dev = F._ctx().device
        tile._write(dev.accum, np.zeros(tile.shape, np.float32))

    res = attention(q, k, vt)
    res.output = np.ascontiguousarray(res.output.T)  # host-side transpose back
    return res
