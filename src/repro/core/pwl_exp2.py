"""Piecewise-linear exp2 approximation (paper §3.3, Fig. 5 / Fig. 12).

FSA computes ``exp(x) = exp2(x * log2(e))`` for ``x <= 0`` by splitting the
input into integer and fractional parts::

    x = x_i + x_f,   x_i = ceil(x) integer,   x_f = x - x_i in (-1, 0]
    exp2(x) = 2**x_i * 2**x_f
    2**x_f  ~= slope_k * x_f + intercept_k,   k = segment index

``2**x_f`` lies in (0.5, 1] so a K-segment *uniform* chord interpolation on
(-1, 0] is accurate to ~1e-2 relative error with K = 8 (the paper's choice).
The ``2**x_i`` factor is applied as an exponent-field update (``ldexp``) —
on FSA hardware this only touches the exponent bits of the result.

All intercepts lie in (0.5, 1] (paper §3.3): the chord through
``(a_k, 2**a_k)`` and ``(b_k, 2**b_k)`` extrapolated to ``x_f = 0`` stays in
that range, which is what lets FSA encode the segment index in the intercept
exponent MSBs.  We assert this property in the tests.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np

DEFAULT_SEGMENTS = 8

__all__ = [
    "DEFAULT_SEGMENTS",
    "segment_table",
    "pwl_coeffs",
    "packed_coeff_table",
    "pwl_exp2",
    "pwl_exp",
    "exp2_reference",
]


@functools.lru_cache(maxsize=None)
def segment_table(num_segments: int = DEFAULT_SEGMENTS) -> tuple[np.ndarray, np.ndarray]:
    """Chord-interpolation (slope, intercept) tables for 2**x_f on (-1, 0].

    Segment k covers ``[-1 + k/K, -1 + (k+1)/K)``; the chord passes through
    the exact endpoints, so the approximation is continuous and exact at the
    K+1 breakpoints (in particular exp2(0) == 1 exactly).
    """
    k = np.arange(num_segments, dtype=np.float64)
    a = -1.0 + k / num_segments
    b = -1.0 + (k + 1.0) / num_segments
    fa, fb = np.exp2(a), np.exp2(b)
    slope = (fb - fa) * num_segments
    intercept = fa - slope * a
    return slope.astype(np.float32), intercept.astype(np.float32)


def pwl_coeffs(
    idx: jax.Array,
    num_segments: int,
    tables: "tuple[jax.Array, jax.Array] | None" = None,
) -> tuple[jax.Array, jax.Array]:
    """(slope, intercept) per element from the segment index, vectorized.

    A single one-hot contraction over a trailing [K] dim: one compare plus
    two multiply-accumulate reductions, instead of a K-deep jnp.where
    chain.  Bit-identical to selecting from the table — exactly one one-hot
    term is nonzero, and its product with the fp32 coefficient is exact.
    Uses broadcasted_iota (TPU needs >=2D iota) so it lowers inside Pallas
    kernel bodies, where vector gathers don't.

    ``tables`` supplies the [K] slope/intercept vectors when they are
    already loaded (Pallas kernels must receive them as inputs — captured
    constant arrays are rejected); defaults to the module table.
    """
    if tables is None:
        slope_t, intercept_t = segment_table(num_segments)
        slope_t, intercept_t = jnp.asarray(slope_t), jnp.asarray(intercept_t)
    else:
        slope_t, intercept_t = tables
    seg = jax.lax.broadcasted_iota(
        jnp.int32, (*idx.shape, num_segments), idx.ndim
    )
    onehot = (idx[..., None] == seg).astype(jnp.float32)
    slope = jnp.sum(onehot * slope_t, axis=-1)
    intercept = jnp.sum(onehot * intercept_t, axis=-1)
    return slope, intercept


def packed_coeff_table(num_segments: int, lanes: int = 128) -> np.ndarray:
    """Slope/intercept packed as one lane-aligned [2, lanes] fp32 array —
    the form the Pallas kernels take as an input operand."""
    slope_t, intercept_t = segment_table(num_segments)
    packed = np.zeros((2, max(lanes, num_segments)), np.float32)
    packed[0, :num_segments] = slope_t
    packed[1, :num_segments] = intercept_t
    return packed


def _split_int_frac(x: jax.Array) -> tuple[jax.Array, jax.Array]:
    """x = x_i + x_f with x_i integer and x_f in (-1, 0] (requires x <= 0)."""
    x_i = jnp.ceil(x)
    x_f = x - x_i
    return x_i, x_f


def pwl_exp2(x: jax.Array, num_segments: int = DEFAULT_SEGMENTS) -> jax.Array:
    """FSA's piecewise-linear exp2 for non-positive inputs.

    Matches the hardware semantics: computation in fp32 (the MAC accumulates
    in fp32), the 2**x_i factor applied as an exponent shift, inputs below
    the fp32 underflow threshold flushed to zero (the paper flushes
    subnormals, §6.2.1).
    """
    slope_np, intercept_np = segment_table(num_segments)
    slope = jnp.asarray(slope_np)
    intercept = jnp.asarray(intercept_np)

    orig_dtype = x.dtype
    xf32 = x.astype(jnp.float32)
    x_i, x_f = _split_int_frac(xf32)

    # Segment index: uniform split of (-1, 0] into K pieces.
    idx = jnp.clip(
        jnp.floor((x_f + 1.0) * num_segments).astype(jnp.int32), 0, num_segments - 1
    )
    frac_pow = slope[idx] * x_f + intercept[idx]  # one MAC per element

    # 2**x_i via exponent update.  Clamp to avoid ldexp overflow on garbage
    # (positive) inputs; FSA only ever sees x <= 0 here.
    e = jnp.clip(x_i, -150.0, 127.0).astype(jnp.int32)
    out = jnp.ldexp(frac_pow, e)
    # Flush-to-zero below the smallest normal of the *input* precision family,
    # mirroring accelerators that do not produce subnormals (§6.2.1).
    out = jnp.where(x_i < -148, 0.0, out)
    return out.astype(orig_dtype)


LOG2_E = float(np.log2(np.e))


def pwl_exp(x: jax.Array, num_segments: int = DEFAULT_SEGMENTS) -> jax.Array:
    """exp(x) = exp2(x * log2 e) with the PWL exp2 (x <= 0)."""
    return pwl_exp2(x.astype(jnp.float32) * LOG2_E, num_segments=num_segments)


def exp2_reference(x: jax.Array) -> jax.Array:
    """Exact exp2 evaluated in fp64-on-CPU / fp32 elsewhere, for error analysis."""
    return jnp.exp2(x)


def pwl_error_stats(num_segments: int = DEFAULT_SEGMENTS) -> dict[str, float]:
    """Exhaustive error over all negative *normal* fp16 values (paper §6.2.1).

    Returns mean absolute error and mean relative error of the PWL exp2
    against fp64 ground truth; reproduces Fig. 12 (8 segments: MAE ~1.4e-4,
    MRE ~2.7e-2).
    """
    # All negative normal fp16: sign=1, exponent in [1, 30], mantissa 0..1023.
    bits = np.arange(0, 1 << 15, dtype=np.uint16)
    vals = (bits | np.uint16(0x8000)).view(np.float16)
    mask = np.isfinite(vals) & (vals < 0) & (np.abs(vals) >= 2.0 ** -14)
    x = vals[mask].astype(np.float32)

    def _ftz16(v: np.ndarray) -> np.ndarray:
        """Round to fp16 and flush subnormal results to zero (§6.2.1)."""
        h = v.astype(np.float16)
        h[np.abs(h.astype(np.float64)) < 2.0 ** -14] = 0
        return h.astype(np.float64)

    # Accelerator output: fp16 with subnormal results flushed to zero.
    approx = _ftz16(
        np.asarray(pwl_exp2(jnp.asarray(x), num_segments=num_segments), dtype=np.float64)
    )
    # Ground truth: exact exp2 rounded to fp16 *keeping* subnormals (the
    # software reference, e.g. torch fp16).  The mismatch in subnormal
    # handling is exactly why the paper's MRE plateaus near 2.7e-2 while the
    # MAE keeps shrinking with more segments (Fig. 12): outputs in
    # [2^-24, 2^-14) are representable by the reference but flushed by the
    # accelerator, a relative error of 1 independent of the interpolation.
    exact = np.exp2(x.astype(np.float64)).astype(np.float16).astype(np.float64)
    abs_err = np.abs(approx - exact)
    # Per-point relative error, with 0/0 (both sides an exact zero for
    # x <= -25) counted as zero error; the mean runs over all evaluated
    # points, matching the paper's reported MRE = 0.02728 at 8 segments.
    nz = exact > 0
    rel_err = np.zeros_like(abs_err)
    rel_err[nz] = abs_err[nz] / exact[nz]
    return {
        "num_segments": float(num_segments),
        "count": float(x.size),
        "mae": float(abs_err.mean()),
        "mre": float(rel_err.mean()),
        "max_abs": float(abs_err.max()),
    }
