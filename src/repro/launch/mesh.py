"""Production mesh construction.

Defined as functions (never module-level constants) so importing this module
never touches jax device state — required because the dry-run must set
XLA_FLAGS before any jax initialization.
"""

from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    """16x16 = 256 chips per pod; (pod=2, data=16, model=16) = 512 chips."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    types = (jax.sharding.AxisType.Auto,) * len(axes)
    return jax.make_mesh(shape, axes, axis_types=types)


def make_debug_mesh(data: int = 1, model: int = 1):
    """Tiny mesh for CPU tests (requires xla_force_host_platform_device_count)."""
    types = (jax.sharding.AxisType.Auto,) * 2
    return jax.make_mesh((data, model), ("data", "model"), axis_types=types)


def data_axes(mesh) -> tuple[str, ...]:
    """Axes that carry data parallelism (pod folds into DP by default)."""
    return ("pod", "data") if "pod" in mesh.axis_names else ("data",)


def model_axis_size(mesh) -> int:
    return mesh.shape["model"]
