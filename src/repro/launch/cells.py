"""Dry-run cell machinery: abstract inputs + lower/compile one
(architecture x input-shape x mesh) combination.

Everything here works on ShapeDtypeStructs — no parameter or cache is ever
allocated; ``lower_cell(...).compile()`` is the proof that the sharding
config is coherent for the production mesh.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Optional

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs.base import SHAPES, ModelConfig, ShapeConfig
from repro.configs.registry import get_config
from repro.dist.sharding import (
    batch_pspec,
    cache_shardings,
    param_shardings,
    zero1_shardings,
)
from repro.models.model import init_cache, param_shapes
from repro.optim.adamw import make_optimizer
from repro.serve.serve_step import make_decode_step, make_prefill_step
from repro.train.train_step import make_train_step

# Archs whose optimizer state must be Adafactor + ZeRO-1 to fit HBM
# (see EXPERIMENTS.md memory table).
ADAFACTOR_ARCHS = {"arctic-480b", "qwen3-moe-235b-a22b"}


def sds(shape, dtype):
    return jax.ShapeDtypeStruct(tuple(shape), dtype)


def input_specs(cfg: ModelConfig, shape: ShapeConfig) -> dict[str, Any]:
    """ShapeDtypeStruct stand-ins for every model input of this cell."""
    gb, s = shape.global_batch, shape.seq_len
    act = cfg.activation_dtype
    if shape.kind in ("train", "prefill"):
        batch: dict[str, Any] = {}
        if cfg.embedding_inputs:
            batch["embeds"] = sds((gb, s, cfg.d_model), act)
        else:
            batch["tokens"] = sds((gb, s), jnp.int32)
        if cfg.mrope_sections is not None:
            batch["positions"] = sds((gb, s, 3), jnp.int32)
        if shape.kind == "train":
            batch["labels"] = sds((gb, s), jnp.int32)
        return {"batch": batch}
    # decode: one new token against a cache of length seq_len
    cache = jax.eval_shape(lambda: init_cache(cfg, gb, s))
    return {
        "tokens": sds((gb, 1), jnp.int32),
        "position": sds((), jnp.int32),
        "cache": cache,
    }


@dataclasses.dataclass
class LoweredCell:
    arch: str
    shape_name: str
    kind: str
    mesh_desc: str
    lowered: Any
    meta: dict


def _replicated(mesh, tree):
    return jax.tree.map(lambda _: NamedSharding(mesh, P()), tree)


def dryrun_config(cfg: ModelConfig, shape: ShapeConfig, scan_unroll: int = 1) -> ModelConfig:
    """Dry-run cost-accounting overrides (see launch/dryrun.py):

    * fully unroll the attention KV scans so their FLOPs are counted
      (XLA's cost_analysis counts while bodies once), with larger blocks
      so the unrolled HLO stays small;
    * set the layer-scan unroll for the two-point cost extrapolation.
    """
    # Respect explicitly-tuned blocks (hillclimb); default to seq/8 so the
    # unrolled HLO stays small.
    block = max(128, shape.seq_len // 8)
    bq = cfg.attn_block_q if cfg.attn_block_q != 128 else block
    bk = cfg.attn_block_k if cfg.attn_block_k != 128 else block
    return dataclasses.replace(
        cfg,
        scan_unroll=scan_unroll,
        attn_unroll=True,
        attn_block_q=bq,
        attn_block_k=bk,
    )


def lower_cell(
    arch: str,
    shape_name: str,
    mesh,
    *,
    cfg_override: Optional[ModelConfig] = None,
    scan_unroll: int = 0,  # 0 = plain production config (no dry-run overrides)
    num_microbatches: int = 1,
    donate: bool = True,
) -> LoweredCell:
    cfg = cfg_override or get_config(arch)
    shape = SHAPES[shape_name]
    if scan_unroll:
        cfg = dryrun_config(cfg, shape, scan_unroll)
    pshapes = param_shapes(cfg)
    pshard = param_shardings(pshapes, cfg, mesh)
    specs = input_specs(cfg, shape)
    mesh_desc = "x".join(str(mesh.shape[a]) for a in mesh.axis_names)

    with jax.set_mesh(mesh):
        if shape.kind == "train":
            opt_name = "adafactor" if arch in ADAFACTOR_ARCHS else "adamw"
            optimizer = make_optimizer(opt_name, lr=3e-4)
            oshapes = jax.eval_shape(optimizer.init, pshapes)
            oshard = zero1_shardings(oshapes, cfg, mesh)
            bshard = batch_pspec(specs["batch"], mesh, cfg)
            step = make_train_step(cfg, optimizer, num_microbatches=num_microbatches)
            jitted = jax.jit(
                step,
                in_shardings=(pshard, oshard, bshard),
                out_shardings=(pshard, oshard, _replicated(mesh, {"loss": 0, "grad_norm": 0})),
                donate_argnums=(0, 1) if donate else (),
            )
            lowered = jitted.lower(pshapes, oshapes, specs["batch"])
            meta = {"optimizer": opt_name}
        elif shape.kind == "prefill":
            bshard = batch_pspec(specs["batch"], mesh, cfg)
            step = make_prefill_step(cfg)
            jitted = jax.jit(step, in_shardings=(pshard, bshard))
            lowered = jitted.lower(pshapes, specs["batch"])
            meta = {}
        else:  # decode
            cshard = cache_shardings(specs["cache"], cfg, mesh)
            tshard = batch_pspec({"tokens": specs["tokens"]}, mesh)["tokens"]
            posshard = NamedSharding(mesh, P())
            step = make_decode_step(cfg)
            jitted = jax.jit(
                step,
                in_shardings=(pshard, cshard, tshard, posshard),
                out_shardings=(tshard, None, cshard),
                donate_argnums=(1,) if donate else (),
            )
            lowered = jitted.lower(
                pshapes, specs["cache"], specs["tokens"], specs["position"]
            )
            meta = {}

    meta.update(
        {
            "params": int(cfg.param_count()),
            "active_params": int(cfg.active_param_count()),
            "global_batch": shape.global_batch,
            "seq_len": shape.seq_len,
        }
    )
    return LoweredCell(arch, shape_name, shape.kind, mesh_desc, lowered, meta)
