import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (architecture x input-shape)
cell on the production mesh and print memory/cost/roofline analysis.

The two lines above MUST run before any other import (jax locks the device
count at first init), which is why they precede the module docstring.

Usage:
  PYTHONPATH=src python -m repro.launch.dryrun --arch olmo-1b --shape train_4k
  PYTHONPATH=src python -m repro.launch.dryrun --all [--multi-pod] [--json out.json]
  PYTHONPATH=src python -m repro.launch.dryrun --all --both-meshes --json out.json
"""

import argparse  # noqa: E402
import json  # noqa: E402
import time  # noqa: E402
import traceback  # noqa: E402

import jax  # noqa: E402

from repro.configs.registry import get_config, runnable_cells, skipped_cells  # noqa: E402
from repro.launch.cells import lower_cell  # noqa: E402
from repro.launch.mesh import make_production_mesh  # noqa: E402
from repro.launch.roofline import analyze_compiled, model_flops  # noqa: E402


def run_cell(arch: str, shape_name: str, multi_pod: bool, verbose: bool = True) -> dict:
    mesh = make_production_mesh(multi_pod=multi_pod)
    chips = mesh.size
    cfg = get_config(arch)
    steps = cfg.num_scan_steps

    # XLA's cost_analysis counts while-loop bodies once, so compile twice —
    # layer-scan unroll=1 and unroll=2 — and extrapolate the exact totals:
    #   F(u) counts c(u) = u + steps%u layer bodies  ->  f = ΔF/Δc,
    #   corrected = F1 + (steps - c(1)) * f.
    t0 = time.time()
    cell = lower_cell(arch, shape_name, mesh, scan_unroll=1)
    t_lower = time.time() - t0
    t0 = time.time()
    compiled = cell.lowered.compile()
    t_compile = time.time() - t0

    mem = compiled.memory_analysis()
    terms = analyze_compiled(compiled, chips)
    # The multi-pod pass proves the 'pod' axis shards (one compile); exact
    # cost extrapolation is needed only for the single-pod roofline table.
    if steps > 1 and not multi_pod:
        cell2 = lower_cell(arch, shape_name, mesh, scan_unroll=2)
        t0 = time.time()
        compiled2 = cell2.lowered.compile()
        t_compile += time.time() - t0
        terms2 = analyze_compiled(compiled2, chips)
        c1, c2 = 1, 2 + steps % 2
        scale = (steps - c1) / (c2 - c1)
        terms.flops = terms.flops + scale * (terms2.flops - terms.flops)
        terms.bytes_accessed = terms.bytes_accessed + scale * (
            terms2.bytes_accessed - terms.bytes_accessed
        )
        terms.coll_bytes = terms.coll_bytes + scale * (
            terms2.coll_bytes - terms.coll_bytes
        )
    tokens = cell.meta["global_batch"] * (
        cell.meta["seq_len"] if cell.kind in ("train", "prefill") else 1
    )
    mf = model_flops(cell.meta["active_params"], tokens, cell.kind)
    flops_source = "hlo_extrapolated"
    if cfg.family == "ssm" and mf > terms.flops:
        # xLSTM's per-token recurrence is a nested time scan whose body XLA
        # also counts once; no finite unroll fixes 4096+ steps, so fall back
        # to the analytic 6·N·D (2·N·D decode) model FLOPs for this family.
        terms.flops = mf
        flops_source = "model_flops (xLSTM time-scan bodies counted once)"
    bytes_per_device = (
        mem.argument_size_in_bytes + mem.output_size_in_bytes + mem.temp_size_in_bytes
        - mem.alias_size_in_bytes
    )
    rec = {
        "arch": arch,
        "shape": shape_name,
        "kind": cell.kind,
        "mesh": cell.mesh_desc,
        "chips": chips,
        "status": "ok",
        "lower_s": round(t_lower, 1),
        "compile_s": round(t_compile, 1),
        "bytes_per_device": int(bytes_per_device),
        "gb_per_device": round(bytes_per_device / 2**30, 3),
        "hlo_flops": terms.flops,
        "hlo_bytes": terms.bytes_accessed,
        "collective_bytes": terms.coll_bytes,
        "collective_breakdown": terms.coll_breakdown,
        "t_compute_s": terms.t_compute,
        "t_memory_s": terms.t_memory,
        "t_collective_s": terms.t_collective,
        "bottleneck": terms.bottleneck,
        "flops_source": flops_source,
        "model_flops": mf,
        "useful_flops_ratio": mf / terms.flops if terms.flops else 0.0,
        "roofline_fraction": terms.roofline_fraction(),
        **cell.meta,
    }
    if verbose:
        print(f"== {arch} x {shape_name} on {cell.mesh_desc} ({chips} chips) ==")
        print(f"  lower {t_lower:.1f}s compile {t_compile:.1f}s")
        print(f"  memory_analysis: {mem}")
        print(
            f"  per-device bytes: {rec['gb_per_device']} GiB  "
            f"(v5e HBM 16 GiB: {'FITS' if bytes_per_device < 16*2**30 else 'OVER'})"
        )
        print(
            f"  roofline terms: compute {terms.t_compute*1e3:.2f} ms | "
            f"memory {terms.t_memory*1e3:.2f} ms | "
            f"collective {terms.t_collective*1e3:.2f} ms -> {terms.bottleneck}-bound"
        )
        print(
            f"  MODEL_FLOPS/HLO_FLOPS = {rec['useful_flops_ratio']:.3f}  "
            f"roofline fraction = {rec['roofline_fraction']:.3f}"
        )
    return rec


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch")
    ap.add_argument("--shape")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--both-meshes", action="store_true")
    ap.add_argument("--json", default=None)
    ap.add_argument("--start", type=int, default=0, help="skip first N cells")
    ap.add_argument("--limit", type=int, default=0)
    args = ap.parse_args()

    assert jax.device_count() == 512, (
        f"dry-run needs 512 placeholder devices, got {jax.device_count()}"
    )

    if args.all:
        cells = runnable_cells()[args.start:]
        if args.limit:
            cells = cells[: args.limit]
    else:
        assert args.arch and args.shape, "--arch/--shape or --all"
        cells = [(args.arch, args.shape)]

    meshes = [False, True] if args.both_meshes else [args.multi_pod]
    records = []

    def dump():
        if args.json:
            with open(args.json, "w") as f:
                json.dump(records, f, indent=1, default=str)

    for multi_pod in meshes:
        for arch, shape_name in cells:
            try:
                records.append(run_cell(arch, shape_name, multi_pod))
            except Exception as e:  # a failure here is a sharding bug
                traceback.print_exc()
                records.append(
                    {
                        "arch": arch,
                        "shape": shape_name,
                        "mesh": "2x16x16" if multi_pod else "16x16",
                        "status": f"FAIL: {type(e).__name__}: {str(e)[:300]}",
                    }
                )
            dump()  # incremental: survive interruption
    for arch, shape_name, reason in skipped_cells():
        records.append(
            {"arch": arch, "shape": shape_name, "status": f"skipped: {reason}"}
        )

    n_ok = sum(1 for r in records if r.get("status") == "ok")
    n_fail = sum(1 for r in records if str(r.get("status", "")).startswith("FAIL"))
    print(f"\n=== dry-run summary: {n_ok} ok, {n_fail} FAILED, "
          f"{len(records) - n_ok - n_fail} skipped ===")
    if args.json:
        with open(args.json, "w") as f:
            json.dump(records, f, indent=1, default=str)
        print(f"wrote {args.json}")
    if n_fail:
        raise SystemExit(1)


if __name__ == "__main__":
    main()
