"""FSA design-space autotune launcher.

  PYTHONPATH=src python -m repro.launch.tune --preset smoke --seed 0 \
      --out tune_report.md --json BENCH_tune.json

Runs the ``repro.tune`` subsystem end to end: builds the preset's design
space, evaluates it sharded over the local device mesh (8 virtual CPU
devices in CI), extracts the Pareto frontier over (TFLOP/s, area, Table 2
error), cross-checks the evaluators against the paper's published numbers
and spot-checks frontier points through the instruction-level simulator.
Deterministic given ``--seed``: re-running regenerates byte-identical
JSON.

  --preset paper|smoke|ci|full   design space (paper = the single
                                 published point, i.e. Fig. 11 + Table 2
                                 + Table 3 as the special case)
  --search grid|random|sha       exhaustive sweep / random sample /
                                 successive halving (multi-fidelity)
  --no-mesh                      evaluate on one device (no shard_map)
  --accuracy-seq N               override the Table 2 protocol length
"""

from __future__ import annotations

import argparse


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--preset", default="smoke",
                    choices=("paper", "smoke", "ci", "full"))
    ap.add_argument("--search", default="grid", choices=("grid", "random", "sha"))
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--points", type=int, default=32,
                    help="sample size for --search random")
    ap.add_argument("--accuracy-seq", type=int, default=None)
    ap.add_argument("--paper-check-seq", type=int, default=2048)
    ap.add_argument("--sim-checks", type=int, default=3)
    ap.add_argument("--no-mesh", action="store_true")
    ap.add_argument("--out", default="tune_report.md", help="markdown report path")
    ap.add_argument("--json", default="BENCH_tune.json", help="JSON payload path")
    args = ap.parse_args()

    from repro.tune import render_markdown, run_tune, write_report

    report = run_tune(
        args.preset,
        search=args.search,
        seed=args.seed,
        mesh=not args.no_mesh,
        num_points=args.points,
        accuracy_seq=args.accuracy_seq,
        paper_check_seq=args.paper_check_seq,
        sim_check_count=args.sim_checks,
    )
    write_report(report, md_path=args.out, json_path=args.json)
    print(render_markdown(report))
    print(f"wrote {args.out} and {args.json}")
    if not (report["paper_checks_ok"] and report["sim_checks_ok"]):
        raise SystemExit("paper/sim cross-checks FAILED — see report")


if __name__ == "__main__":
    main()
