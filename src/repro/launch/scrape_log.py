"""Recover structured records from run logs.

Two sources, newest first:

  * **JSONL fast path** — the trainer (``TrainerConfig.metrics_jsonl``,
    wired to ``launch/train.py --metrics-out``) streams one JSON object
    per step; any log whose lines parse as JSON objects is consumed
    verbatim, no regexes.
  * **Regex fallback** — dryrun stdout logs (for runs interrupted before
    their JSON dump) are scraped with the original pattern set.

Usage:

  PYTHONPATH=src python -m repro.launch.scrape_log run_log.txt out.json
"""

from __future__ import annotations

import json
import re
import sys


def scrape_jsonl(text: str) -> list[dict]:
    """Collect every line that parses as a JSON object (the trainer's
    metrics stream; interleaved non-JSON lines — human log lines, tracebacks
    — are skipped)."""
    records = []
    for line in text.splitlines():
        line = line.strip()
        if not (line.startswith("{") and line.endswith("}")):
            continue
        try:
            obj = json.loads(line)
        except json.JSONDecodeError:
            continue
        if isinstance(obj, dict):
            records.append(obj)
    return records


def scrape_dryrun(text: str) -> list[dict]:
    """Regex path: reconstruct dryrun records from stdout."""
    records = []
    cur = None
    for line in text.splitlines():
        m = re.match(r"== (\S+) x (\S+) on (\S+) \((\d+) chips\) ==", line)
        if m:
            if cur:
                records.append(cur)
            cur = {
                "arch": m.group(1),
                "shape": m.group(2),
                "mesh": m.group(3),
                "chips": int(m.group(4)),
                "status": "ok",
            }
            continue
        if cur is None:
            continue
        m = re.search(r"lower ([\d.]+)s compile ([\d.]+)s", line)
        if m:
            cur["lower_s"], cur["compile_s"] = float(m.group(1)), float(m.group(2))
        m = re.search(r"per-device bytes: ([\d.]+) GiB", line)
        if m:
            cur["gb_per_device"] = float(m.group(1))
            cur["bytes_per_device"] = int(float(m.group(1)) * 2**30)
        m = re.search(
            r"compute ([\d.]+) ms \| memory ([\d.]+) ms \| collective ([\d.]+) ms -> (\w+)-bound",
            line,
        )
        if m:
            cur["t_compute_s"] = float(m.group(1)) / 1e3
            cur["t_memory_s"] = float(m.group(2)) / 1e3
            cur["t_collective_s"] = float(m.group(3)) / 1e3
            cur["bottleneck"] = m.group(4)
        m = re.search(
            r"MODEL_FLOPS/HLO_FLOPS = ([\d.]+)\s+roofline fraction = ([\d.]+)", line
        )
        if m:
            cur["useful_flops_ratio"] = float(m.group(1))
            cur["roofline_fraction"] = float(m.group(2))
    if cur:
        records.append(cur)
    return records


def scrape(text: str) -> list[dict]:
    """JSONL fast path when the log carries structured records, else the
    dryrun regex fallback."""
    records = scrape_jsonl(text)
    return records if records else scrape_dryrun(text)


def main() -> None:
    src, dst = sys.argv[1], sys.argv[2]
    records = scrape(open(src, errors="replace").read())
    with open(dst, "w") as f:
        json.dump(records, f, indent=1)
    print(f"scraped {len(records)} records -> {dst}")


if __name__ == "__main__":
    main()
