"""Render dryrun_results.json into the EXPERIMENTS.md §Dry-run / §Roofline
markdown tables.

  PYTHONPATH=src python -m repro.launch.report dryrun_results.json
"""

from __future__ import annotations

import json
import sys


def fmt_bytes(b: float) -> str:
    if b >= 2**30:
        return f"{b / 2**30:.2f}G"
    if b >= 2**20:
        return f"{b / 2**20:.1f}M"
    return f"{b / 2**10:.0f}K"


def fmt_t(s: float) -> str:
    if s >= 1.0:
        return f"{s:.2f}s"
    return f"{s * 1e3:.1f}ms"


def dryrun_table(records: list[dict]) -> str:
    lines = [
        "| arch | shape | mesh | compile | GiB/dev | fits 16G | status |",
        "|---|---|---|---|---|---|---|",
    ]
    for r in records:
        if r.get("status") != "ok":
            lines.append(
                f"| {r['arch']} | {r['shape']} | {r.get('mesh','-')} | - | - | - | {r['status']} |"
            )
            continue
        fits = "yes" if r["bytes_per_device"] < 16 * 2**30 else "**over**"
        lines.append(
            f"| {r['arch']} | {r['shape']} | {r['mesh']} | {r['compile_s']}s "
            f"| {r['gb_per_device']} | {fits} | ok |"
        )
    return "\n".join(lines)


def roofline_table(records: list[dict]) -> str:
    lines = [
        "| arch | shape | mesh | t_compute | t_memory | t_collective | bound | 6ND/HLO | roofline frac |",
        "|---|---|---|---|---|---|---|---|---|",
    ]
    for r in records:
        if r.get("status") != "ok":
            continue
        lines.append(
            f"| {r['arch']} | {r['shape']} | {r['mesh']} "
            f"| {fmt_t(r['t_compute_s'])} | {fmt_t(r['t_memory_s'])} "
            f"| {fmt_t(r['t_collective_s'])} | {r['bottleneck']} "
            f"| {r['useful_flops_ratio']:.2f} | {r['roofline_fraction']:.3f} |"
        )
    return "\n".join(lines)


def pick_hillclimb(records: list[dict]) -> list[dict]:
    ok = [r for r in records if r.get("status") == "ok" and r["mesh"] == "16x16"]
    if not ok:
        return []
    worst = min(ok, key=lambda r: r["roofline_fraction"])
    coll = max(ok, key=lambda r: r["t_collective_s"] / max(r["t_compute_s"], 1e-30))
    # most representative of the paper's technique: biggest attention share
    # ~ prefill of a big dense model
    prefill = [r for r in ok if r["shape"] == "prefill_32k"]
    rep = max(prefill, key=lambda r: r["t_compute_s"]) if prefill else worst
    out, seen = [], set()
    for r in (worst, coll, rep):
        key = (r["arch"], r["shape"])
        if key not in seen:
            seen.add(key)
            out.append(r)
    return out


def main() -> None:
    path = sys.argv[1] if len(sys.argv) > 1 else "dryrun_results.json"
    records = json.load(open(path))
    print("### Dry-run table\n")
    print(dryrun_table(records))
    print("\n### Roofline table\n")
    print(roofline_table(records))
    print("\n### Hillclimb candidates\n")
    for r in pick_hillclimb(records):
        print(f"- {r['arch']} x {r['shape']}: bound={r['bottleneck']} "
              f"frac={r['roofline_fraction']:.3f}")


if __name__ == "__main__":
    main()
