"""Production training launcher.

  PYTHONPATH=src python -m repro.launch.train --arch olmo-1b --steps 50 \
      --batch 8 --seq 256 [--smoke] [--ckpt-dir DIR] [--resume]

``--smoke`` uses the arch's reduced config (CPU-runnable); the full config
is what the multi-pod dry-run lowers.  On a real TPU slice this same entry
point runs under the production mesh with the sharding rules from
repro.dist.sharding; ``--mesh DxM`` stands one up from the local devices.

  --quant int8         int8 projections (quantization-aware: the backward
                       is straight-through against fp operands)
  --compress-grads     int8 DP gradient reduction with error feedback
  --mesh DxM           debug mesh (data x model), e.g. --mesh 2x1
  --metrics-out PATH   Prometheus text dump at exit (loss/gnorm gauges,
                       step-latency histogram, MFU, watchdog heartbeats);
                       additionally streams one JSON record per step to
                       PATH.jsonl (scrape_log's fast path)
  --trace-out PATH     Chrome-trace/Perfetto JSON of the per-step spans
"""

from __future__ import annotations

import argparse
import dataclasses

from repro.configs.base import ShapeConfig
from repro.configs.registry import get_config, get_smoke_config
from repro.obs import Tracer, set_tracer
from repro.quant.config import QUANT_FLAGS
from repro.train.trainer import Trainer, TrainerConfig


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true", help="reduced config (CPU)")
    ap.add_argument("--steps", type=int, default=50)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=256)
    ap.add_argument("--microbatches", type=int, default=1)
    ap.add_argument("--optimizer", default="adamw", choices=("adamw", "adafactor"))
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_ckpt")
    ap.add_argument("--ckpt-every", type=int, default=25)
    ap.add_argument("--token-file", default=None)
    ap.add_argument("--quant", default="none", choices=QUANT_FLAGS)
    ap.add_argument("--compress-grads", action="store_true",
                    help="int8-compressed DP gradient reduction")
    ap.add_argument("--mesh", default=None, help="debug mesh DxM, e.g. 2x1")
    ap.add_argument("--metrics-out", default=None, metavar="PATH",
                    help="Prometheus dump at exit + per-step PATH.jsonl stream")
    ap.add_argument("--trace-out", default=None, metavar="PATH",
                    help="write a Perfetto-loadable Chrome trace here")
    args = ap.parse_args()

    cfg = (get_smoke_config if args.smoke else get_config)(args.arch, args.quant)
    if cfg.family == "encoder" and not cfg.embedding_inputs:
        raise SystemExit("encoder archs train on frame embeddings")
    shape = ShapeConfig("cli", args.seq, args.batch, "train")
    tcfg = TrainerConfig(
        total_steps=args.steps,
        ckpt_every=args.ckpt_every,
        ckpt_dir=args.ckpt_dir,
        optimizer=args.optimizer,
        peak_lr=args.lr,
        num_microbatches=args.microbatches,
        log_every=max(args.steps // 10, 1),
        compress_grads=args.compress_grads,
        metrics_jsonl=args.metrics_out + ".jsonl" if args.metrics_out else None,
    )
    mesh = None
    if args.mesh:
        from repro.launch.mesh import make_debug_mesh

        data, model = (int(x) for x in args.mesh.split("x"))
        mesh = make_debug_mesh(data, model)
    tracer = None
    if args.trace_out:
        tracer = Tracer(process_name=f"train {args.arch}")
        set_tracer(tracer)
    trainer = Trainer(
        cfg, shape, tcfg, token_file=args.token_file, mesh=mesh, tracer=tracer
    )
    state = trainer.run()
    print(f"done at step {state['step']}; "
          f"loss {state['losses'][0]:.4f} -> {state['losses'][-1]:.4f}")
    mfu = trainer.registry.get("mfu")
    if mfu is not None:
        print(f"mfu (train, vs FSA array peak): {mfu.labels(phase='train').value:.3e}")
    if args.metrics_out:
        trainer.registry.dump(args.metrics_out)
        print(f"metrics -> {args.metrics_out} (+ {tcfg.metrics_jsonl})")
    if tracer is not None:
        tracer.save(args.trace_out)
        print(f"trace ({len(tracer.events)} events) -> {args.trace_out}")


if __name__ == "__main__":
    main()
