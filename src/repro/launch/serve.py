"""Serving launcher: continuous batching against a (smoke-config) model.

  PYTHONPATH=src python -m repro.launch.serve --arch yi-9b --requests 8

Requests get mixed prompt lengths (the engine buckets them for prefill),
arrive all at once, and drain through a fixed slot pool — so this drives
prefill bucketing, slot eviction and back-fill even in a smoke run.

  --temperature/--top-k/--top-p  sampling policy (default greedy)
  --chunk N                      chunked flash prefill (N tokens per call)
  --mesh DxM                     shard params + decode cache over a debug
                                 mesh (data x model), e.g. --mesh 2x4
  --quant int8                   int8 projections + int8 KV cache
                                 (repro.quant; greedy outputs stay
                                 token-identical to sequential decode,
                                 so --check still applies)
  --spec-draft self|ARCH         speculative decoding (repro.spec): 'self'
                                 drafts with the target itself (lossless
                                 sanity mode, acceptance = 1.0); an arch id
                                 drafts with that smoke config (random
                                 init in this launcher)
  --spec-k N                     lookahead: draft tokens verified per round
  --spec-quant int8              int8 policy on the *draft* only (the
                                 near-free draft / exact target split)
  --check                        verify every greedy output token-for-token
                                 against sequential single-request decode
  --metrics-out PATH             dump the engine's metrics registry as
                                 Prometheus text at exit (TTFT/TPOT/queue
                                 histograms, occupancy + MFU gauges, jit
                                 compile counters)
  --trace-out PATH               save a Chrome-trace/Perfetto JSON of the
                                 run (open at ui.perfetto.dev)
"""

from __future__ import annotations

import argparse
import contextlib
import time

import jax
import numpy as np

from repro.configs.registry import get_smoke_config
from repro.models import init_params
from repro.obs import Tracer, set_tracer, watch_jit_compiles
from repro.quant.config import QUANT_FLAGS
from repro.serve import Request, SamplingConfig, ServeEngine, sequential_greedy_decode


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="yi-9b")
    ap.add_argument("--quant", default="none", choices=QUANT_FLAGS,
                    help="int8 policy: projections + int8 KV cache "
                         "(int8-kv-only / int8-no-kv select one half)")
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--prompt-len", type=int, default=12,
                    help="max prompt length; actual lengths are mixed in [2, N]")
    ap.add_argument("--max-new", type=int, default=8)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--max-len", type=int, default=128)
    ap.add_argument("--chunk", type=int, default=None)
    ap.add_argument("--temperature", type=float, default=0.0)
    ap.add_argument("--top-k", type=int, default=0)
    ap.add_argument("--top-p", type=float, default=1.0)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--mesh", default=None, help="debug mesh DxM, e.g. 2x4")
    ap.add_argument("--spec-draft", default=None,
                    help="speculative decoding draft: 'self' or an arch id")
    ap.add_argument("--spec-k", type=int, default=4,
                    help="speculative lookahead (draft tokens per round)")
    ap.add_argument("--spec-quant", default="none", choices=QUANT_FLAGS,
                    help="int8 policy applied to the draft model only")
    ap.add_argument("--check", action="store_true",
                    help="compare against sequential single-request decode")
    ap.add_argument("--metrics-out", default=None, metavar="PATH",
                    help="write Prometheus text exposition here at exit")
    ap.add_argument("--trace-out", default=None, metavar="PATH",
                    help="write a Perfetto-loadable Chrome trace here")
    args = ap.parse_args()

    cfg = get_smoke_config(args.arch, args.quant)
    if cfg.family == "encoder":
        raise SystemExit("encoder-only arch: no decode phase (DESIGN.md §5)")

    mesh = None
    params = init_params(cfg, jax.random.PRNGKey(0))
    if args.mesh:
        from repro.dist.sharding import param_shardings
        from repro.launch.mesh import make_debug_mesh

        data, model = (int(x) for x in args.mesh.split("x"))
        mesh = make_debug_mesh(data, model)
        params = jax.device_put(params, param_shardings(params, cfg, mesh))

    sampling = SamplingConfig(
        temperature=args.temperature, top_k=args.top_k, top_p=args.top_p,
        seed=args.seed,
    )

    spec = draft_params = None
    if args.spec_draft:
        from repro.spec import SpecConfig, resolve_draft_config

        spec = SpecConfig(
            draft_arch=None if args.spec_draft == "self" else args.spec_draft,
            draft_quant=args.spec_quant if args.spec_quant != "none" else None,
            lookahead=args.spec_k,
        )
        if spec.draft_arch is not None:
            # No trained weights in this launcher: a random-init draft still
            # exercises the full draft->verify->rollback path (outputs stay
            # lossless; only the acceptance rate suffers).
            draft_params = init_params(
                resolve_draft_config(spec, cfg), jax.random.PRNGKey(1)
            )

    tracer = None
    if args.trace_out:
        tracer = Tracer(process_name=f"serve {args.arch}")
        set_tracer(tracer)

    engine = ServeEngine(
        cfg, params, batch_size=args.batch, max_len=args.max_len,
        prefill_chunk=args.chunk, sampling=sampling, mesh=mesh,
        spec=spec, draft_params=draft_params, tracer=tracer,
    )

    rng = np.random.default_rng(0)
    prompts = {}
    for i in range(args.requests):
        plen = int(rng.integers(2, max(3, args.prompt_len + 1)))
        prompts[i] = rng.integers(0, cfg.vocab_size, size=plen).astype(np.int32)
        engine.submit(Request(rid=i, prompt=prompts[i], max_new_tokens=args.max_new))

    # With a metrics sink requested, also count XLA executable builds into
    # the registry (jax's compile log fires once per build).
    compile_watch = (
        watch_jit_compiles(
            engine.registry.counter(
                "jit_compiles_total", "XLA executable builds observed"
            )
        )
        if args.metrics_out else contextlib.nullcontext()
    )
    t0 = time.perf_counter()
    with compile_watch:
        done = engine.run()
    dt = time.perf_counter() - t0

    for r in sorted(done, key=lambda r: r.rid):
        print(f"req {r.rid}: prompt[{len(prompts[r.rid])}] -> {r.output}")
    toks = sum(len(r.output) for r in done)
    print(
        f"completed {len(done)}/{args.requests}: {toks} tokens in {dt:.2f}s "
        f"({toks / dt:.1f} tok/s) | stats {engine.stats} "
        f"| compiles {engine.compile_counts()}"
    )
    if spec is not None:
        print(
            f"spec: acceptance {engine.acceptance_rate():.3f} | "
            f"{engine.stats['verify_steps']} verify steps for {toks} tokens "
            f"({toks / max(engine.stats['verify_steps'], 1):.2f} tok/verify)"
        )

    ttft = engine.registry.get("serve_ttft_seconds")
    tpot = engine.registry.get("serve_tpot_seconds")
    print(
        f"latency: ttft p50 {ttft.percentile(50) * 1e3:.1f} ms "
        f"p99 {ttft.percentile(99) * 1e3:.1f} ms | "
        f"tpot p50 {tpot.percentile(50) * 1e3:.1f} ms "
        f"p99 {tpot.percentile(99) * 1e3:.1f} ms"
    )
    if args.metrics_out:
        engine.registry.dump(args.metrics_out)
        print(f"metrics -> {args.metrics_out}")
    if tracer is not None:
        tracer.save(args.trace_out)
        print(f"trace ({len(tracer.events)} events) -> {args.trace_out}")

    if args.check:
        if not sampling.greedy:
            raise SystemExit("--check requires greedy decoding (temperature 0)")
        bad = 0
        for r in sorted(done, key=lambda r: r.rid):
            ref = sequential_greedy_decode(
                cfg, params, prompts[r.rid], args.max_new, max_len=args.max_len
            )
            if r.output != ref:
                bad += 1
                print(f"MISMATCH req {r.rid}: engine {r.output} != ref {ref}")
        if bad:
            raise SystemExit(f"{bad}/{len(done)} requests diverged")
        print(f"check OK: all {len(done)} outputs match sequential decode")


if __name__ == "__main__":
    main()
