"""Serving launcher: batched requests against a (smoke-config) model.

  PYTHONPATH=src python -m repro.launch.serve --arch yi-9b --requests 8
"""

from __future__ import annotations

import argparse

import jax
import numpy as np

from repro.configs.registry import get_smoke_config
from repro.models import init_params
from repro.serve.engine import Request, ServeEngine


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="yi-9b")
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--prompt-len", type=int, default=12)
    ap.add_argument("--max-new", type=int, default=8)
    ap.add_argument("--batch", type=int, default=4)
    args = ap.parse_args()

    cfg = get_smoke_config(args.arch)
    if cfg.family == "encoder":
        raise SystemExit("encoder-only arch: no decode phase (DESIGN.md §5)")
    params = init_params(cfg, jax.random.PRNGKey(0))
    engine = ServeEngine(cfg, params, batch_size=args.batch, max_len=128)

    rng = np.random.default_rng(0)
    for i in range(args.requests):
        engine.submit(
            Request(
                rid=i,
                prompt=rng.integers(0, cfg.vocab_size, size=args.prompt_len).astype(np.int32),
                max_new_tokens=args.max_new,
            )
        )
    done = engine.run()
    for r in sorted(done, key=lambda r: r.rid):
        print(f"req {r.rid}: -> {r.output}")
    print(f"completed {len(done)}/{args.requests}")


if __name__ == "__main__":
    main()
