"""Roofline-term extraction from compiled dry-run artifacts.

Per the brief (TPU v5e targets):
  compute term    = HLO_FLOPs / (chips x 197e12 FLOP/s)
  memory term     = HLO_bytes / (chips x 819e9 B/s)
  collective term = collective operand bytes / (chips x 50e9 B/s per link)

HLO_FLOPs / bytes come from ``compiled.cost_analysis()``.  Collective bytes
are NOT in cost_analysis: we parse the optimized HLO text and sum the
operand sizes of every all-gather / all-reduce / reduce-scatter /
all-to-all / collective-permute op.
"""

from __future__ import annotations

import dataclasses
import re

PEAK_FLOPS = 197e12  # bf16 per chip
HBM_BW = 819e9  # bytes/s per chip
LINK_BW = 50e9  # bytes/s per ICI link

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "s32": 4, "u32": 4,
    "s64": 8, "u64": 8, "f16": 2, "bf16": 2, "f32": 4, "f64": 8,
    "c64": 8, "c128": 16, "f8e4m3fn": 1, "f8e5m2": 1,
}

_COLLECTIVE_RE = re.compile(
    r"^\s*(?:%\S+\s*=\s*)?"
    r"(?:\(?[a-z0-9\[\]{}, ـ/_.\-]*\)?\s*)?"
    r"(all-gather|all-reduce|reduce-scatter|all-to-all|collective-permute)"
    r"(?:-start|-done)?\(",
    re.IGNORECASE,
)

_SHAPE_RE = re.compile(r"(pred|[suf]\d+|bf16|f8e4m3fn|f8e5m2|c64|c128)\[([\d,]*)\]")


def _shape_bytes(shape_str: str) -> int:
    total = 0
    for dt, dims in _SHAPE_RE.findall(shape_str):
        n = 1
        if dims:
            for d in dims.split(","):
                if d:
                    n *= int(d)
        total += n * _DTYPE_BYTES.get(dt, 4)
    return total


def collective_bytes(hlo_text: str) -> dict[str, int]:
    """Sum *output* shape bytes per collective kind from optimized HLO.

    Each collective line looks like::

        %ag = bf16[16,4096]{...} all-gather(bf16[1,4096]{...} %x), ...

    We count the result shape (the data volume that crosses links, up to a
    kind-dependent constant) and report per-kind totals.
    """
    out: dict[str, int] = {}
    for line in hlo_text.splitlines():
        m = re.search(
            r"=\s*([^=]*?)\s*(all-gather|all-reduce|reduce-scatter|all-to-all|"
            r"collective-permute)(?:-start)?\(",
            line,
        )
        if not m:
            continue
        kind = m.group(2).lower()
        result_shape = m.group(1)
        b = _shape_bytes(result_shape)
        if b:
            out[kind] = out.get(kind, 0) + b
    return out


@dataclasses.dataclass
class RooflineTerms:
    flops: float
    bytes_accessed: float
    coll_bytes: float
    coll_breakdown: dict
    chips: int
    out_bytes_per_device: float

    @property
    def t_compute(self) -> float:
        return self.flops / (self.chips * PEAK_FLOPS)

    @property
    def t_memory(self) -> float:
        return self.bytes_accessed / (self.chips * HBM_BW)

    @property
    def t_collective(self) -> float:
        return self.coll_bytes / (self.chips * LINK_BW)

    @property
    def bottleneck(self) -> str:
        terms = {
            "compute": self.t_compute,
            "memory": self.t_memory,
            "collective": self.t_collective,
        }
        return max(terms, key=terms.get)

    @property
    def step_time(self) -> float:
        """Lower-bound step time: max of the three overlappable terms."""
        return max(self.t_compute, self.t_memory, self.t_collective)

    def roofline_fraction(self) -> float:
        """Fraction of the compute roofline this step achieves if the
        dominant term were perfectly overlapped: t_compute / step_time."""
        return self.t_compute / max(self.step_time, 1e-30)


def analyze_compiled(compiled, chips: int) -> RooflineTerms:
    cost = compiled.cost_analysis()
    if isinstance(cost, list):  # some backends return [dict]
        cost = cost[0]
    # cost_analysis() reports the *per-device* SPMD module (verified on the
    # CPU backend: an 8-way sharded matmul reports dense_flops/8).  Scale to
    # global so the brief's global/(chips*peak) formulas apply.
    flops = float(cost.get("flops", 0.0)) * chips
    byts = float(cost.get("bytes accessed", 0.0)) * chips
    try:
        hlo = compiled.as_text()
    except Exception:
        hlo = ""
    coll = collective_bytes(hlo)
    # Collective result shapes in the per-device HLO approximate the bytes
    # crossing each device's links; x chips = whole-system volume.
    coll_total = float(sum(coll.values()))
    try:
        mem = compiled.memory_analysis()
        out_bytes = float(getattr(mem, "output_size_in_bytes", 0))
    except Exception:
        out_bytes = 0.0
    return RooflineTerms(
        flops=flops,
        bytes_accessed=byts,
        coll_bytes=coll_total * chips,  # scale to whole-system volume
        coll_breakdown=coll,
        chips=chips,
        out_bytes_per_device=out_bytes,
    )


def model_flops(active_params: int, tokens: int, kind: str) -> float:
    """6·N·D for training, 2·N·D for inference forward."""
    mult = 6.0 if kind == "train" else 2.0
    return mult * active_params * tokens
