"""Labeled Counter/Gauge/Histogram registry with Prometheus/JSON exposition.

Zero-dependency (stdlib only) metrics substrate for the whole repo: the
serve engine, the trainer, and the fault-tolerance layer all report through
a ``Registry``.  Design points:

  * **prometheus_client-shaped API** — ``registry.counter(name, help,
    labelnames)`` returns a family; ``family.labels(phase="decode").inc()``
    addresses a child; families with no labelnames delegate directly
    (``family.inc()``).
  * **Fixed-bucket histograms** for exposition (cumulative ``_bucket{le=}``
    series, Prometheus semantics) plus a bounded reservoir of raw samples
    so ``percentile(q)`` matches ``numpy.percentile`` exactly until the
    reservoir cap, then degrades to a sliding-window estimate.
  * **Global off switch** — ``set_enabled(False)`` turns every mutation
    (``inc``/``set``/``observe``) into a guarded early return; the no-op
    overhead is pinned near-zero by ``tests/test_obs.py``.
  * ``snapshot()`` exports a nested plain dict (JSON-able); ``to_prometheus()``
    emits the text exposition format; ``to_json()`` is ``snapshot()`` dumped.

``JitCompileWatcher`` generalizes the test suite's XLA-compile-counting
fixture into a library counter: it hooks jax's ``jax_log_compiles`` log
records (one per executable build, cache hits silent) and can forward each
build into a registry counter.
"""

from __future__ import annotations

import bisect
import json
import logging
import math
import threading
from collections import deque
from contextlib import contextmanager
from typing import Iterable, Optional

__all__ = [
    "Counter",
    "Gauge",
    "Histogram",
    "Registry",
    "DEFAULT_BUCKETS",
    "default_registry",
    "enabled",
    "set_enabled",
    "JitCompileWatcher",
    "watch_jit_compiles",
]


class _State:
    """Module-global enable flag.  An object attribute (not a bare module
    global) so the hot-path check is one LOAD_ATTR and ``set_enabled``
    never has to touch importers' references."""

    __slots__ = ("on",)

    def __init__(self):
        self.on = True


_STATE = _State()


def enabled() -> bool:
    return _STATE.on


def set_enabled(flag: bool) -> None:
    """Globally enable/disable all metric mutations (no-op path when off)."""
    _STATE.on = bool(flag)


# Latency-oriented default buckets: 10 µs .. 60 s, roughly x2.5 per step.
DEFAULT_BUCKETS = (
    1e-5, 2.5e-5, 5e-5, 1e-4, 2.5e-4, 5e-4,
    1e-3, 2.5e-3, 5e-3, 1e-2, 2.5e-2, 5e-2,
    0.1, 0.25, 0.5, 1.0, 2.5, 5.0, 10.0, 30.0, 60.0,
)

# Raw-sample reservoir per histogram child; under this many observations the
# percentile math is exact (numpy-equivalent), beyond it a sliding window.
DEFAULT_SAMPLE_CAP = 8192


def _fmt(v: float) -> str:
    """Prometheus float formatting: integers bare, else repr."""
    if v == math.inf:
        return "+Inf"
    if float(v).is_integer() and abs(v) < 1e15:
        return str(int(v))
    return repr(float(v))


def _label_str(labelnames: tuple, key: tuple) -> str:
    if not labelnames:
        return ""
    inner = ",".join(f'{n}="{v}"' for n, v in zip(labelnames, key))
    return "{" + inner + "}"


class _Family:
    """Base for the three metric families: owns the (labelvalues -> child)
    map and delegates mutations to the default (unlabeled) child."""

    kind = "untyped"

    def __init__(self, name: str, help: str, labelnames: Iterable[str] = ()):
        self.name = name
        self.help = help
        self.labelnames = tuple(labelnames)
        self._children: dict[tuple, object] = {}
        self._lock = threading.Lock()

    def _make_child(self):  # pragma: no cover - overridden
        raise NotImplementedError

    def labels(self, *values, **kv):
        if kv:
            if values:
                raise ValueError("pass label values positionally or by name")
            values = tuple(kv[n] for n in self.labelnames)
        if len(values) != len(self.labelnames):
            raise ValueError(
                f"{self.name}: expected labels {self.labelnames}, got {values}"
            )
        key = tuple(str(v) for v in values)
        child = self._children.get(key)
        if child is None:
            with self._lock:
                child = self._children.setdefault(key, self._make_child())
        return child

    def _default(self):
        if self.labelnames:
            raise ValueError(f"{self.name} is labeled; address it via .labels()")
        return self.labels()

    def children(self) -> dict[tuple, object]:
        return dict(self._children)


class _CounterChild:
    __slots__ = ("value",)

    def __init__(self):
        self.value = 0.0

    def inc(self, n: float = 1.0) -> None:
        if not _STATE.on:
            return
        if n < 0:
            raise ValueError("counters only go up")
        self.value += n


class Counter(_Family):
    kind = "counter"

    def _make_child(self):
        return _CounterChild()

    def inc(self, n: float = 1.0) -> None:
        self._default().inc(n)

    @property
    def value(self) -> float:
        return self._default().value


class _GaugeChild:
    __slots__ = ("value",)

    def __init__(self):
        self.value = 0.0

    def set(self, v: float) -> None:
        if not _STATE.on:
            return
        self.value = float(v)

    def inc(self, n: float = 1.0) -> None:
        if not _STATE.on:
            return
        self.value += n

    def dec(self, n: float = 1.0) -> None:
        self.inc(-n)


class Gauge(_Family):
    kind = "gauge"

    def _make_child(self):
        return _GaugeChild()

    def set(self, v: float) -> None:
        self._default().set(v)

    def inc(self, n: float = 1.0) -> None:
        self._default().inc(n)

    def dec(self, n: float = 1.0) -> None:
        self._default().dec(n)

    @property
    def value(self) -> float:
        return self._default().value


class _HistogramChild:
    __slots__ = ("uppers", "bucket_counts", "sum", "count", "samples")

    def __init__(self, buckets: tuple, sample_cap: int):
        self.uppers = buckets
        self.bucket_counts = [0] * (len(buckets) + 1)  # + overflow (+Inf)
        self.sum = 0.0
        self.count = 0
        self.samples: deque = deque(maxlen=sample_cap)

    def observe(self, v: float) -> None:
        if not _STATE.on:
            return
        v = float(v)
        self.bucket_counts[bisect.bisect_left(self.uppers, v)] += 1
        self.sum += v
        self.count += 1
        self.samples.append(v)

    def percentile(self, q: float) -> float:
        """q in [0, 100]; numpy-style linear interpolation over the retained
        sample reservoir (exact while count <= sample cap)."""
        if not self.samples:
            return 0.0
        s = sorted(self.samples)
        if len(s) == 1:
            return s[0]
        rank = (q / 100.0) * (len(s) - 1)
        lo = int(math.floor(rank))
        hi = min(lo + 1, len(s) - 1)
        frac = rank - lo
        return s[lo] * (1.0 - frac) + s[hi] * frac

    def summary(self) -> dict:
        return {
            "count": self.count,
            "sum": self.sum,
            "p50": self.percentile(50),
            "p90": self.percentile(90),
            "p99": self.percentile(99),
        }

    def cumulative_buckets(self) -> list[tuple[float, int]]:
        """Prometheus-style (le, cumulative_count) rows, ending at +Inf."""
        rows, cum = [], 0
        for upper, c in zip(self.uppers, self.bucket_counts):
            cum += c
            rows.append((upper, cum))
        rows.append((math.inf, cum + self.bucket_counts[-1]))
        return rows


class Histogram(_Family):
    kind = "histogram"

    def __init__(
        self,
        name: str,
        help: str,
        labelnames: Iterable[str] = (),
        *,
        buckets: tuple = DEFAULT_BUCKETS,
        sample_cap: int = DEFAULT_SAMPLE_CAP,
    ):
        super().__init__(name, help, labelnames)
        self.buckets = tuple(sorted(buckets))
        self.sample_cap = sample_cap

    def _make_child(self):
        return _HistogramChild(self.buckets, self.sample_cap)

    def observe(self, v: float) -> None:
        self._default().observe(v)

    def percentile(self, q: float) -> float:
        return self._default().percentile(q)

    def summary(self) -> dict:
        return self._default().summary()

    @property
    def count(self) -> int:
        return self._default().count

    @property
    def sum(self) -> float:
        return self._default().sum


class Registry:
    """Named metric store.  ``counter/gauge/histogram`` are idempotent
    get-or-create (re-registering the same name with the same kind returns
    the existing family)."""

    def __init__(self):
        self._metrics: dict[str, _Family] = {}
        self._lock = threading.Lock()

    def _register(self, cls, name, help, labelnames, **kw) -> _Family:
        with self._lock:
            existing = self._metrics.get(name)
            if existing is not None:
                if not isinstance(existing, cls):
                    raise ValueError(
                        f"{name} already registered as {existing.kind}"
                    )
                return existing
            fam = cls(name, help, labelnames, **kw)
            self._metrics[name] = fam
            return fam

    def counter(self, name, help="", labelnames=()) -> Counter:
        return self._register(Counter, name, help, labelnames)

    def gauge(self, name, help="", labelnames=()) -> Gauge:
        return self._register(Gauge, name, help, labelnames)

    def histogram(
        self, name, help="", labelnames=(),
        buckets=DEFAULT_BUCKETS, sample_cap=DEFAULT_SAMPLE_CAP,
    ) -> Histogram:
        return self._register(
            Histogram, name, help, labelnames,
            buckets=buckets, sample_cap=sample_cap,
        )

    def get(self, name: str) -> Optional[_Family]:
        return self._metrics.get(name)

    def metrics(self) -> dict[str, _Family]:
        return dict(self._metrics)

    # -- export ------------------------------------------------------------

    def snapshot(self) -> dict:
        """Nested plain-dict export: kind -> name -> labelstring -> value
        (histograms export their percentile summary)."""
        out: dict = {"counters": {}, "gauges": {}, "histograms": {}}
        for name, fam in sorted(self._metrics.items()):
            vals = {}
            for key, child in sorted(fam.children().items()):
                lk = _label_str(fam.labelnames, key)
                if fam.kind == "histogram":
                    vals[lk] = child.summary()
                else:
                    vals[lk] = child.value
            out[fam.kind + "s"][name] = vals
        return out

    def to_json(self, indent: Optional[int] = None) -> str:
        return json.dumps(self.snapshot(), indent=indent, sort_keys=True)

    def to_prometheus(self) -> str:
        """Prometheus text exposition format (version 0.0.4)."""
        lines = []
        for name, fam in sorted(self._metrics.items()):
            lines.append(f"# HELP {name} {fam.help}")
            lines.append(f"# TYPE {name} {fam.kind}")
            for key, child in sorted(fam.children().items()):
                ls = _label_str(fam.labelnames, key)
                if fam.kind == "histogram":
                    for upper, cum in child.cumulative_buckets():
                        le = _label_str(
                            fam.labelnames + ("le",), key + (_fmt(upper),)
                        )
                        lines.append(f"{name}_bucket{le} {cum}")
                    lines.append(f"{name}_sum{ls} {_fmt(child.sum)}")
                    lines.append(f"{name}_count{ls} {_fmt(float(child.count))}")
                else:
                    lines.append(f"{name}{ls} {_fmt(child.value)}")
        return "\n".join(lines) + "\n"

    def dump(self, path: str) -> None:
        with open(path, "w") as f:
            f.write(self.to_prometheus())


_DEFAULT = Registry()


def default_registry() -> Registry:
    """The process-global registry (ad-hoc consumers; subsystems that need
    isolation — e.g. one ``ServeEngine`` per registry — create their own)."""
    return _DEFAULT


# ---------------------------------------------------------------------------
# XLA compile-event counter (library form of the ``jit_recompiles`` fixture)
# ---------------------------------------------------------------------------


class JitCompileWatcher(logging.Handler):
    """Counts XLA executable builds via jax's ``jax_log_compiles`` records
    ("Finished XLA compilation of <name> in <t> sec"), which fire exactly
    once per build — jit cache hits are silent.  Optionally forwards each
    build into a registry counter (child or unlabeled family)."""

    def __init__(self, counter=None):
        super().__init__(level=logging.DEBUG)
        self.count = 0
        self.counter = counter

    def emit(self, record):
        if "Finished XLA compilation" in record.getMessage():
            self.count += 1
            if self.counter is not None:
                self.counter.inc()

    def reset(self):
        self.count = 0

    def install(self):
        import jax

        self._prev = jax.config.jax_log_compiles
        jax.config.update("jax_log_compiles", True)
        logging.getLogger("jax").addHandler(self)
        return self

    def uninstall(self):
        import jax

        logging.getLogger("jax").removeHandler(self)
        jax.config.update("jax_log_compiles", getattr(self, "_prev", False))


@contextmanager
def watch_jit_compiles(counter=None):
    """Context manager: yields an installed ``JitCompileWatcher``."""
    watcher = JitCompileWatcher(counter).install()
    try:
        yield watcher
    finally:
        watcher.uninstall()
