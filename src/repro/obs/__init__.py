"""repro.obs — unified telemetry: metrics registry, tracing, MFU accounting.

Zero-dependency observability substrate (ISSUE 10).  Three pieces:

  * :mod:`repro.obs.metrics` — labeled Counter/Gauge/Histogram registry
    with Prometheus-text and JSON exposition, percentile summaries, a
    global off switch whose no-op path costs ~a guarded return, and the
    XLA compile-event watcher.
  * :mod:`repro.obs.trace` — Chrome-trace/Perfetto span + event tracer
    (``{"ph": "X", "ts": ...}``) with ``jax.profiler.TraceAnnotation``
    pass-through; ``NullTracer`` is the free disabled twin.
  * :mod:`repro.obs.mfu` — model-FLOPs-utilization accounting against the
    paper's FSA array peak, reusing ``core.systolic_model`` closed forms
    for the Fig. 11 paper-ideal reference.

The serve engine, trainer, and fault-tolerance layer all report through
this package; ``launch/serve.py --metrics-out m.prom --trace-out t.json``
(and the train launcher) dump the exposition files at exit.
"""

from .metrics import (
    DEFAULT_BUCKETS,
    Counter,
    Gauge,
    Histogram,
    JitCompileWatcher,
    Registry,
    default_registry,
    enabled,
    set_enabled,
    watch_jit_compiles,
)
from .mfu import (
    PAPER_ARRAY,
    ArrayConfig,
    MFUMeter,
    decode_flops,
    paper_ideal_flops_per_s,
    prefill_flops,
    train_step_flops,
    verify_flops,
)
from .trace import NullTracer, Tracer, get_tracer, set_tracer

__all__ = [
    "Counter",
    "Gauge",
    "Histogram",
    "Registry",
    "DEFAULT_BUCKETS",
    "default_registry",
    "enabled",
    "set_enabled",
    "JitCompileWatcher",
    "watch_jit_compiles",
    "Tracer",
    "NullTracer",
    "get_tracer",
    "set_tracer",
    "ArrayConfig",
    "PAPER_ARRAY",
    "MFUMeter",
    "train_step_flops",
    "prefill_flops",
    "decode_flops",
    "verify_flops",
    "paper_ideal_flops_per_s",
]
