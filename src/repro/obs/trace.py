"""Span/event tracing in Chrome-trace (Perfetto-loadable) JSON.

``Tracer`` collects Trace Event Format records — complete spans
(``"ph": "X"`` with ``ts``/``dur``) and instant events (``"ph": "i"``) —
and ``save()``s them as ``{"traceEvents": [...]}``, the JSON object form
chrome://tracing and ui.perfetto.dev both load.  Timestamps are
microseconds on a per-tracer monotonic epoch (``time.perf_counter``).

Spans come in two forms:

  * ``with tracer.span("prefill", args={"rid": 3}):`` — measures the
    enclosed block.  When jax exposes ``jax.profiler.TraceAnnotation`` the
    span name is passed through to it too, so the same annotation shows up
    in a jax-native profile when one is being captured.
  * ``tracer.complete(name, start_s, dur_s)`` — retroactive span from
    host-side timestamps already on hand (e.g. a request's queue-wait
    window emitted at retire time).

``NullTracer`` is the disabled twin: every method is a no-op and ``span``
is a reusable null context manager, so instrumented code needs no
``if tracing:`` guards.  The module-global tracer (``get_tracer``)
defaults to the null tracer; launchers swap in a real one for
``--trace-out``.
"""

from __future__ import annotations

import contextlib
import json
import os
import threading
import time
from typing import Optional

__all__ = ["Tracer", "NullTracer", "get_tracer", "set_tracer"]


def _jax_trace_annotation():
    """``jax.profiler.TraceAnnotation`` when this jax has it, else None.
    Resolved lazily so importing repro.obs never forces jax init."""
    try:
        import jax

        return getattr(jax.profiler, "TraceAnnotation", None)
    except Exception:  # pragma: no cover - jax always importable here
        return None


class Tracer:
    """Chrome-trace event collector.  Thread-safe appends; ``tid`` selects
    the lane (default: per-thread ident, or pass one explicitly to group
    logical tracks such as request slots)."""

    def __init__(self, *, process_name: str = "repro", pid: int = 0):
        self.pid = pid
        self.events: list[dict] = []
        self._lock = threading.Lock()
        self._epoch = time.perf_counter()
        self._annotation = _jax_trace_annotation()
        # Metadata record naming the process lane in the Perfetto UI.
        self.events.append(
            {
                "ph": "M",
                "name": "process_name",
                "pid": pid,
                "tid": 0,
                "args": {"name": process_name},
            }
        )

    # -- clock -------------------------------------------------------------

    def now_s(self) -> float:
        """Seconds on this tracer's epoch (pair with ``complete``)."""
        return time.perf_counter() - self._epoch

    def _us(self, t_s: float) -> float:
        return t_s * 1e6

    # -- emission ----------------------------------------------------------

    def _append(self, ev: dict) -> None:
        with self._lock:
            self.events.append(ev)

    @contextlib.contextmanager
    def span(self, name: str, *, cat: str = "", tid: Optional[int] = None,
             args: Optional[dict] = None):
        """Measure the enclosed block as a complete ("X") event."""
        tid = threading.get_ident() % 2**31 if tid is None else tid
        t0 = self.now_s()
        ann = self._annotation(name) if self._annotation is not None else None
        if ann is not None:
            ann.__enter__()
        try:
            yield self
        finally:
            if ann is not None:
                ann.__exit__(None, None, None)
            self.complete(name, t0, self.now_s() - t0, cat=cat, tid=tid,
                          args=args)

    def complete(self, name: str, start_s: float, dur_s: float, *,
                 cat: str = "", tid: int = 0,
                 args: Optional[dict] = None) -> None:
        """Retroactive span from host timestamps on this tracer's epoch."""
        ev = {
            "name": name,
            "cat": cat or "repro",
            "ph": "X",
            "ts": self._us(start_s),
            "dur": max(self._us(dur_s), 0.0),
            "pid": self.pid,
            "tid": tid,
        }
        if args:
            ev["args"] = dict(args)
        self._append(ev)

    def complete_abs(self, name: str, start_perf: float, end_perf: float, *,
                     cat: str = "", tid: int = 0,
                     args: Optional[dict] = None) -> None:
        """Retroactive span from raw ``time.perf_counter()`` timestamps
        (instrumented code keeps perf_counter values; this converts onto
        the tracer epoch)."""
        self.complete(name, start_perf - self._epoch, end_perf - start_perf,
                      cat=cat, tid=tid, args=args)

    def instant(self, name: str, *, cat: str = "", tid: int = 0,
                args: Optional[dict] = None) -> None:
        ev = {
            "name": name,
            "cat": cat or "repro",
            "ph": "i",
            "s": "t",  # scope: thread
            "ts": self._us(self.now_s()),
            "pid": self.pid,
            "tid": tid,
        }
        if args:
            ev["args"] = dict(args)
        self._append(ev)

    def thread_name(self, tid: int, name: str) -> None:
        """Label a lane (e.g. ``slot 3``) in the Perfetto track list."""
        self._append(
            {"ph": "M", "name": "thread_name", "pid": self.pid, "tid": tid,
             "args": {"name": name}}
        )

    # -- export ------------------------------------------------------------

    def to_dict(self) -> dict:
        with self._lock:
            return {"traceEvents": list(self.events), "displayTimeUnit": "ms"}

    def save(self, path: str) -> str:
        d = os.path.dirname(path)
        if d:
            os.makedirs(d, exist_ok=True)
        with open(path, "w") as f:
            json.dump(self.to_dict(), f)
        return path


class NullTracer:
    """Disabled tracer: structurally API-compatible, allocation-free."""

    events: tuple = ()

    @contextlib.contextmanager
    def span(self, name, *, cat="", tid=None, args=None):
        yield self

    def now_s(self) -> float:
        return 0.0

    def complete(self, *a, **k) -> None:
        pass

    def complete_abs(self, *a, **k) -> None:
        pass

    def instant(self, *a, **k) -> None:
        pass

    def thread_name(self, *a, **k) -> None:
        pass

    def to_dict(self) -> dict:
        return {"traceEvents": [], "displayTimeUnit": "ms"}

    def save(self, path: str) -> str:  # pragma: no cover - debugging aid
        with open(path, "w") as f:
            json.dump(self.to_dict(), f)
        return path


NULL_TRACER = NullTracer()
_current = NULL_TRACER


def get_tracer():
    """The ambient tracer (``NullTracer`` unless a launcher installed one)."""
    return _current


def set_tracer(tracer) -> None:
    global _current
    _current = tracer if tracer is not None else NULL_TRACER
