"""Model-FLOPs-utilization (MFU) accounting against the paper's FSA array.

The paper's headline metric (Fig. 11) is attention FLOPs/s utilization:
achieved FLOPs divided by the array's peak.  This module makes the repo
report that metric about its *own* execution:

  * closed-form model FLOPs per phase — PaLM-appendix accounting
    (2 FLOPs per active parameter per token forward, 3x for the backward
    pass) plus the causal attention term ``4 * ctx * head_dim * heads``
    per token per layer, specialized for train / prefill / decode /
    speculative-verify calls;
  * the **paper-ideal** reference reuses ``core.systolic_model`` verbatim:
    ``fsa_utilization(seq)`` times the array's peak is what FSA achieves
    on that attention shape per Fig. 11, so ``mfu / ideal`` says how far
    this host run sits from the paper's own ceiling;
  * ``MFUMeter`` folds both into a ``repro.obs`` registry as per-phase
    gauges (``model_flops_per_s``, ``mfu``, ``paper_ideal_utilization``,
    ``mfu_vs_paper_ideal``) and a cumulative FLOPs counter.

On this CPU container the absolute MFU is of course minuscule — the point
is the plumbing: the same meter pointed at a real array reads directly in
the paper's units.
"""

from __future__ import annotations

import dataclasses
from typing import Optional

import numpy as np

from repro.configs.base import ModelConfig
from repro.core import systolic_model

__all__ = [
    "ArrayConfig",
    "PAPER_ARRAY",
    "train_step_flops",
    "prefill_flops",
    "decode_flops",
    "verify_flops",
    "paper_ideal_flops_per_s",
    "MFUMeter",
]


@dataclasses.dataclass(frozen=True)
class ArrayConfig:
    """The systolic array the MFU denominator refers to (paper Table 1:
    N = 128 at 1.5 GHz; ``tune.DesignPoint`` uses the same defaults)."""

    array_n: int = 128
    freq_ghz: float = 1.5
    single_direction: bool = False

    @property
    def peak_flops_per_s(self) -> float:
        """2 * N^2 MACs-as-FLOPs per cycle at the synthesis clock."""
        return 2.0 * self.array_n * self.array_n * self.freq_ghz * 1e9


PAPER_ARRAY = ArrayConfig()


# ---------------------------------------------------------------------------
# Model-FLOPs closed forms
# ---------------------------------------------------------------------------


def _attn_flops_per_token(cfg: ModelConfig, context: float) -> float:
    """Score + value matmul FLOPs for one query token attending over
    ``context`` keys: 2 * (QK^T) + 2 * (PV) per head per layer."""
    return 4.0 * context * cfg.resolved_head_dim * cfg.num_heads * cfg.num_layers


def train_step_flops(cfg: ModelConfig, batch: int, seq_len: int) -> float:
    """One optimizer step over ``batch`` sequences of ``seq_len`` tokens:
    6 FLOPs per active param per token (fwd 2 + bwd 4), plus the causal
    attention term (mean context seq/2) at 3x forward cost."""
    tokens = float(batch) * seq_len
    param = 6.0 * cfg.active_param_count() * tokens
    attn = 3.0 * _attn_flops_per_token(cfg, seq_len / 2.0) * tokens
    return param + attn


def prefill_flops(cfg: ModelConfig, prompt_len: int) -> float:
    """Forward over one prompt (causal: token i attends to i+1 keys)."""
    param = 2.0 * cfg.active_param_count() * prompt_len
    attn = _attn_flops_per_token(cfg, (prompt_len + 1) / 2.0) * prompt_len
    return param + attn


def decode_flops(cfg: ModelConfig, contexts) -> float:
    """One batched decode step; ``contexts`` = per-live-slot KV lengths."""
    contexts = np.asarray(contexts, dtype=np.float64)
    n = float(contexts.size)
    param = 2.0 * cfg.active_param_count() * n
    attn = sum(_attn_flops_per_token(cfg, c + 1.0) for c in contexts)
    return param + attn


def verify_flops(cfg: ModelConfig, contexts, k: int) -> float:
    """One speculative verify: K+1 teacher-forced tokens per slot, each
    attending over its (growing) context."""
    total = 0.0
    for c in np.asarray(contexts, dtype=np.float64):
        for j in range(k + 1):
            total += _attn_flops_per_token(cfg, c + j + 1.0)
    param = 2.0 * cfg.active_param_count() * float(len(contexts)) * (k + 1)
    return param + total


def paper_ideal_flops_per_s(
    seq_len: int,
    head_dim: int = 128,
    array: ArrayConfig = PAPER_ARRAY,
) -> float:
    """FLOPs/s FSA achieves on this attention shape per Fig. 11: the
    ``systolic_model`` closed-form utilization times the array peak."""
    util = systolic_model.fsa_utilization(
        seq_len, head_dim, array.array_n,
        single_direction=array.single_direction,
    )
    return util * array.peak_flops_per_s


class MFUMeter:
    """Per-phase MFU gauges on a ``repro.obs`` registry.

    ``record(phase, flops, seconds, seq_len=...)`` computes achieved
    FLOPs/s, divides by the array peak (-> MFU, the Fig. 11 y-axis), and —
    when the phase has a characteristic attention length — also reports
    the paper-ideal utilization at that length and the achieved/ideal
    ratio.  Returns the computed record as a plain dict."""

    def __init__(self, cfg: ModelConfig, registry, *,
                 array: ArrayConfig = PAPER_ARRAY, prefix: str = ""):
        self.cfg, self.array = cfg, array
        p = prefix
        self.registry = registry
        self._flops_total = registry.counter(
            p + "model_flops_total", "cumulative model FLOPs", ("phase",)
        )
        self._flops_per_s = registry.gauge(
            p + "model_flops_per_s", "achieved model FLOPs/s (last call)",
            ("phase",),
        )
        self._mfu = registry.gauge(
            p + "mfu",
            "model FLOPs utilization vs the FSA array peak "
            f"({array.peak_flops_per_s / 1e12:.3f} TFLOP/s)",
            ("phase",),
        )
        self._ideal = registry.gauge(
            p + "paper_ideal_utilization",
            "Fig. 11 FSA utilization at this phase's attention length",
            ("phase",),
        )
        self._vs_ideal = registry.gauge(
            p + "mfu_vs_paper_ideal",
            "achieved utilization / paper-ideal FSA utilization",
            ("phase",),
        )

    def record(self, phase: str, flops: float, seconds: float, *,
               seq_len: Optional[int] = None) -> dict:
        seconds = max(float(seconds), 1e-12)
        fps = flops / seconds
        mfu = fps / self.array.peak_flops_per_s
        self._flops_total.labels(phase=phase).inc(flops)
        self._flops_per_s.labels(phase=phase).set(fps)
        self._mfu.labels(phase=phase).set(mfu)
        rec = {"phase": phase, "flops": flops, "flops_per_s": fps, "mfu": mfu}
        if seq_len is not None and seq_len >= 1:
            ideal = systolic_model.fsa_utilization(
                int(seq_len), self.cfg.resolved_head_dim, self.array.array_n,
                single_direction=self.array.single_direction,
            ) if self.cfg.resolved_head_dim == self.array.array_n else (
                # The closed form maps Bc = N_ROWS = d; for other head dims
                # report utilization at the paper's head_dim instead.
                systolic_model.fsa_utilization(
                    int(seq_len), self.array.array_n, self.array.array_n,
                    single_direction=self.array.single_direction,
                )
            )
            self._ideal.labels(phase=phase).set(ideal)
            self._vs_ideal.labels(phase=phase).set(mfu / ideal)
            rec.update(paper_ideal_utilization=ideal, mfu_vs_paper_ideal=mfu / ideal)
        return rec

    # -- phase-specific conveniences ---------------------------------------

    def train_step(self, batch: int, seq_len: int, seconds: float) -> dict:
        return self.record(
            "train", train_step_flops(self.cfg, batch, seq_len), seconds,
            seq_len=seq_len,
        )

    def prefill(self, prompt_len: int, seconds: float) -> dict:
        return self.record(
            "prefill", prefill_flops(self.cfg, prompt_len), seconds,
            seq_len=prompt_len,
        )

    def decode(self, contexts, seconds: float) -> dict:
        ctx = np.asarray(contexts)
        seq = int(ctx.mean()) + 1 if ctx.size else None
        return self.record(
            "decode", decode_flops(self.cfg, contexts), seconds, seq_len=seq
        )

    def verify(self, contexts, k: int, seconds: float) -> dict:
        ctx = np.asarray(contexts)
        seq = int(ctx.mean()) + k + 1 if ctx.size else None
        return self.record(
            "verify", verify_flops(self.cfg, contexts, k), seconds, seq_len=seq
        )
