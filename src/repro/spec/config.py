"""Speculative-decoding policy configuration.

``SpecConfig`` is the serializable policy the serving engine carries: which
model drafts (an arch id from the registry, or ``None`` for self-draft),
under what quantization, how many tokens it looks ahead per round, and how
proposals are accepted.  Frozen/hashable so it stays a valid jit static
argument alongside ``ModelConfig``.
"""

from __future__ import annotations

import dataclasses
from typing import Optional, Union

from repro.configs.base import ModelConfig
from repro.quant.config import QuantConfig, parse_quant

# Families whose decode cache is a KV cache and therefore supports the
# lengths-truncation rollback spec decoding needs.  Recurrent families
# (hybrid/ssm) carry state that cannot be rolled back by truncation.
ROLLBACK_FAMILIES = ("dense", "moe", "vlm")


@dataclasses.dataclass(frozen=True)
class SpecConfig:
    """Speculative decoding policy.

    ``draft_arch`` names a registry smoke config for the draft model, or
    ``None`` for self-draft (draft == target — the lossless sanity
    configuration whose acceptance rate must be 1.0).  ``draft_quant``
    overlays an int8 policy on the draft only (the target stays whatever
    the engine's config says), per the MatrixFlow co-design framing: a
    near-free int8 draft, exact fp32 verify.  ``lookahead`` is K, the
    number of draft tokens verified per round; each round emits between 1
    and K+1 tokens.
    """

    draft_arch: Optional[str] = None  # None: self-draft (target cfg/params)
    draft_quant: Union[QuantConfig, str, None] = None
    lookahead: int = 4
    acceptance: str = "greedy"

    def __post_init__(self):
        if self.lookahead < 1:
            raise ValueError(f"lookahead must be >= 1, got {self.lookahead}")
        if self.acceptance != "greedy":
            raise ValueError(
                f"unknown acceptance rule {self.acceptance!r} (only 'greedy' "
                f"— exact target-argmax match — is implemented)"
            )
        if isinstance(self.draft_quant, str):
            # Normalize the CLI-flag form eagerly so equal policies hash equal.
            object.__setattr__(self, "draft_quant", parse_quant(self.draft_quant))


def resolve_draft_config(spec: SpecConfig, target: ModelConfig) -> ModelConfig:
    """The draft's ModelConfig: registry smoke config or the target itself,
    with the draft-side quantization overlaid.  Validates that draft and
    target can actually speculate together."""
    if target.family not in ROLLBACK_FAMILIES:
        raise ValueError(
            f"speculative decoding needs a KV-cache target for rollback; "
            f"family {target.family!r} is recurrent"
        )
    if spec.draft_arch is None:
        cfg = target
    else:
        from repro.configs.registry import get_smoke_config

        cfg = get_smoke_config(spec.draft_arch)
    if spec.draft_quant is not None:
        cfg = dataclasses.replace(cfg, quant=spec.draft_quant)
    if cfg.family not in ROLLBACK_FAMILIES:
        raise ValueError(
            f"draft family {cfg.family!r} has no KV rollback; pick an "
            f"attention-family draft"
        )
    if cfg.vocab_size != target.vocab_size:
        raise ValueError(
            f"draft vocab {cfg.vocab_size} != target vocab "
            f"{target.vocab_size}: drafted ids must be valid target inputs"
        )
    return cfg
