"""The draft side of speculative decoding: a small model whose cache
mirrors the engine's slot lifecycle.

The ``DraftWorker`` owns a second, parallel decode cache over the same
slot pool as the target engine: every target prefill/insert is mirrored
here (same bucket padding, same slot), every verify round rolls the draft
back to the target's accepted length.  The invariant maintained across
rounds is that draft ``lengths[i]`` always equals the target's — the draft
has cached exactly the tokens the target accepted — so a round's proposals
start from a synchronized context.

Per round the draft runs K+1 greedy decode steps, not K: the last step
feeds the final proposal ``d_K`` back in (its sampled token is discarded)
purely to write ``d_K``'s K/V.  That keeps the cache dense through
position ``pos + K``, so a fully-accepted round needs no special-case
catch-up next round — rollback to ``pos + K + 1`` always lands on rows
that exist, and self-draft acceptance stays exactly 1.0.
"""

from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.models import init_cache, insert_cache, prefill_step, rollback_cache
from repro.serve.serve_step import make_decode_step


class DraftWorker:
    """Draft-model proposer with a mirrored per-slot decode cache."""

    def __init__(
        self,
        cfg: ModelConfig,
        params,
        *,
        batch_size: int,
        max_len: int,
        prefill_chunk: Optional[int] = None,
    ):
        self.cfg, self.params = cfg, params
        self.batch, self.max_len = batch_size, max_len
        self.prefill_chunk = prefill_chunk
        self.cache = None
        self._positions = np.zeros(batch_size, np.int32)

        def _prefill(params, tokens, true_len):
            bucket = tokens.shape[1]
            cache = init_cache(cfg, 1, bucket)
            _, cache = prefill_step(
                params, cfg, tokens, cache,
                jnp.reshape(true_len, (1,)),
                chunk_size=self.prefill_chunk,
            )
            # The draft's prefill logits are discarded: the first decode
            # token always comes from the *target's* prefill.
            return cache

        def _insert(cache, prefix, slot):
            return insert_cache(cache, prefix, slot)

        def _rollback(cache, new_lengths):
            return rollback_cache(cache, new_lengths)

        self._prefill_jit = jax.jit(_prefill)
        self._insert_jit = jax.jit(_insert)
        self._decode_jit = jax.jit(make_decode_step(cfg))  # greedy 4-arg
        self._rollback_jit = jax.jit(_rollback)

    def compile_counts(self) -> dict:
        return {
            "draft_prefill": self._prefill_jit._cache_size(),
            "draft_insert": self._insert_jit._cache_size(),
            "draft_generate": self._decode_jit._cache_size(),
        }

    def ensure_cache(self) -> None:
        if self.cache is None:
            self.cache = init_cache(self.cfg, self.batch, self.max_len)

    def prefill_into_slot(self, prompt: np.ndarray, slot: int, bucket: int) -> None:
        """Mirror the target's prefill+insert for ``slot`` (same bucket)."""
        self.ensure_cache()
        plen = len(prompt)
        toks = np.zeros((1, bucket), np.int32)
        toks[0, :plen] = prompt
        prefix = self._prefill_jit(
            self.params, jnp.asarray(toks), jnp.asarray(plen, jnp.int32)
        )
        self.cache = self._insert_jit(
            self.cache, prefix, jnp.asarray(slot, jnp.int32)
        )
        self._positions[slot] = plen

    def propose(self, next_tok: np.ndarray, k: int) -> np.ndarray:
        """K greedy draft tokens per slot, [B, K] — plus one extra decode
        step that writes the last proposal's K/V (token discarded)."""
        tok = jnp.asarray(next_tok.reshape(-1, 1).astype(np.int32))
        drafts = []
        for j in range(k + 1):
            tok, _, self.cache = self._decode_jit(
                self.params, self.cache, tok, jnp.asarray(self._positions + j)
            )
            if j < k:
                drafts.append(np.asarray(tok)[:, 0])
        self._positions += k + 1
        return np.stack(drafts, axis=1)

    def rollback(self, new_lengths: np.ndarray) -> None:
        """Truncate to the target's accepted lengths after a verify round."""
        new_lengths = new_lengths.astype(np.int32)
        self.cache = self._rollback_jit(self.cache, jnp.asarray(new_lengths))
        self._positions = new_lengths.copy()
