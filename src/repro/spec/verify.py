"""The batched verify pass: score K drafts per slot, accept, roll back.

One jit call per round replaces up to K+1 sequential target decode steps —
the K small interleaved matmuls the paper says starve a systolic array
become one wide teacher-forced forward (``repro.models.verify_step``),
exactly the consecutive-large-matmul shape the FSA schedule (and the
chunked flash prefill path) is built for.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import rollback_cache, verify_step


def make_spec_verify(cfg: ModelConfig):
    """Build the engine's verify closure.

    ``spec_verify(params, cache, tokens [B, K+1], positions [B])`` returns

      * ``greedy [B, K+1]`` — the target's greedy token at every verified
        position (``greedy[:, j]`` is the argmax given the cached prefix
        plus ``tokens[:, :j+1]``);
      * ``accepted [B]`` — per slot, the length of the longest draft prefix
        the target agrees with (0..K), capped so the emitted run never
        outgrows the cache capacity;
      * the cache with all K+1 rows written and ``lengths`` rolled back to
        ``positions + accepted + 1`` — accepted rows kept, rejected suffix
        truncated.

    Greedy acceptance makes losslessness structural: an accepted draft
    ``tokens[:, j+1]`` *equals* ``greedy[:, j]``, so the emitted stream
    ``greedy[:, :accepted+1]`` is the target's own greedy continuation —
    token-identical to vanilla decode no matter what the draft proposed.
    """

    def spec_verify(params, cache, tokens, positions):
        logits, cache = verify_step(params, cfg, tokens, cache, positions)
        greedy = jnp.argmax(logits.astype(jnp.float32), axis=-1).astype(jnp.int32)
        # accepted = longest prefix with draft[j] == greedy[j]; cumprod
        # zeroes everything after the first mismatch.
        match = (greedy[:, :-1] == tokens[:, 1:]).astype(jnp.int32)
        accepted = jnp.sum(jnp.cumprod(match, axis=1), axis=1)
        max_len = cache.k.shape[2]  # [L, B, max_len, ...]
        cap = jnp.maximum(max_len - positions - 1, 0)
        accepted = jnp.minimum(accepted, cap)
        cache = rollback_cache(cache, positions + accepted + 1)
        return greedy, accepted, cache

    return spec_verify
