"""``repro.spec`` — speculative decoding across the model zoo.

Draft -> batched verify -> cache rollback: a small draft model proposes K
greedy tokens per slot; the target scores all K (+1 bonus) in *one* wide
teacher-forced forward against its live decode cache
(``repro.models.verify_step``); rejected suffixes are rolled back by
per-slot ``lengths`` truncation (``repro.models.rollback_cache``, fp32 and
int8 KV caches alike).  Greedy acceptance is lossless by construction —
the emitted stream is the target's own greedy continuation — so the
serving engine's token-equivalence contract survives speculation intact.

Pieces:
  * ``SpecConfig`` / ``resolve_draft_config`` — the policy (config.py):
    draft arch (or self-draft), draft-side int8 quantization, lookahead K;
  * ``DraftWorker`` — the draft model's mirrored slot-cache lifecycle
    (draft.py);
  * ``make_spec_verify`` — the jitted verify/accept/rollback round
    (verify.py), wired into ``ServeEngine(spec=...)``.
"""

from .config import ROLLBACK_FAMILIES, SpecConfig, resolve_draft_config  # noqa: F401
from .draft import DraftWorker  # noqa: F401
from .verify import make_spec_verify  # noqa: F401
