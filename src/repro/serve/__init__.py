from .engine import (  # noqa: F401
    Request,
    ServeEngine,
    default_buckets,
    sequential_greedy_decode,
)
from .serve_step import (  # noqa: F401
    SamplingConfig,
    make_decode_step,
    make_prefill_step,
    sample_logits,
)
