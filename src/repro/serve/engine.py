"""Continuous-batching serving engine (JetStream/MaxText-style).

Requests flow through three separated phases, each a reused jit executable:

  * **prefill** — the whole (padded) prompt in one jit call: chunked flash
    attention writes K/V straight into a single-request cache
    (``repro.models.prefill_step``; the compute-bound phase the paper
    targets), and the first token is sampled from the last true position's
    logits.  Prompts are padded to a small set of power-of-two *buckets* so
    the executable is compiled once per bucket, never per prompt length.
  * **insert** — the prefilled single-request cache is copied into a free
    batch slot of the shared decode cache (``repro.models.insert_cache``).
  * **generate** — one batched decode step advances *every* live slot by
    one token.  The cache keeps per-slot lengths, so requests with
    different prompt lengths and decode depths coexist in one batch; slots
    retire at EOS/max_tokens/capacity and are back-filled from the queue
    every step.

The engine is family-agnostic (dense/MoE/VLM use the flash prefill path;
hybrid/SSM teacher-force under one ``lax.scan``) and optionally shards the
decode cache over an ambient mesh via ``repro.dist.sharding``.

With ``spec=SpecConfig(...)`` (repro.spec) the generate phase runs
speculatively: a draft model proposes K greedy tokens per slot, the target
verifies all of them in one wide teacher-forced forward against the live
cache, and rejected suffixes roll back by per-slot length truncation.
Greedy outputs stay token-identical to vanilla decode — only the step
count changes.

Telemetry (``repro.obs``): every engine owns a metrics ``Registry`` —
request-lifecycle histograms (``serve_ttft_seconds``,
``serve_tpot_seconds``, ``serve_queue_wait_seconds``), slot-occupancy /
batch-utilization / queue-depth gauges, per-phase jit-executable gauges,
spec acceptance, and per-phase MFU gauges against the paper's FSA array
(``repro.obs.mfu``).  The legacy ``stats`` dict is now a property over the
registry counters.  With a real ``Tracer`` installed (``--trace-out``),
phases emit live spans and each retired request leaves queued/prefill/
decode spans on its slot's lane.
"""

from __future__ import annotations

import contextlib
import dataclasses
import time
from collections import deque
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.models import decode_step, init_cache, insert_cache, prefill_step
from repro.obs import MFUMeter, Registry, get_tracer
from .serve_step import SamplingConfig, make_decode_step, sample_logits


@dataclasses.dataclass(eq=False)
class Request:
    # eq=False: the generated __eq__ would compare the ndarray `prompt`
    # field, making `r in wave` membership raise ("truth value of an array
    # is ambiguous") for distinct same-length prompts.  Requests are
    # identity-equal; `rid` is the stable external key.
    rid: int
    prompt: np.ndarray  # [len] int32 (lists/other int dtypes are coerced)
    max_new_tokens: int = 16
    eos_id: int = -1  # -1: never
    output: list = dataclasses.field(default_factory=list)
    done: bool = False
    # Lifecycle timestamps (engine-clock seconds), filled in by the engine:
    # enqueue -> prefill start -> first token -> last token.  They back the
    # TTFT/TPOT/queue-wait histograms and the per-request trace spans.
    t_submit: Optional[float] = None
    t_prefill: Optional[float] = None
    t_first_token: Optional[float] = None
    t_last_token: Optional[float] = None

    def __post_init__(self):
        # Callers naturally pass Python lists; everything downstream
        # (shape-based bucketing, pad copies) needs ndarray semantics.
        self.prompt = np.asarray(self.prompt, dtype=np.int32)


def default_buckets(max_len: int, lo: int = 16) -> tuple[int, ...]:
    """Power-of-two prefill buckets up to (and excluding padding past)
    ``max_len``: the largest bucket equals the cache capacity."""
    buckets = []
    b = lo
    while b < max_len:
        buckets.append(b)
        b *= 2
    buckets.append(max_len)
    return tuple(buckets)


class ServeEngine:
    """Continuous-batching engine with per-slot cache state."""

    def __init__(
        self,
        cfg: ModelConfig,
        params,
        *,
        batch_size: int = 4,
        max_len: int = 256,
        prefill_chunk: Optional[int] = None,
        prefill_buckets: Optional[tuple[int, ...]] = None,
        sampling: Optional[SamplingConfig] = None,
        mesh=None,
        spec=None,  # Optional[repro.spec.SpecConfig]: speculative decoding
        draft_params=None,  # draft model params (self-draft reuses `params`)
        registry: Optional[Registry] = None,  # repro.obs metrics sink
        tracer=None,  # repro.obs Tracer (default: ambient, usually Null)
    ):
        assert cfg.family != "encoder", "encoder archs have no decode phase"
        self.cfg, self.params = cfg, params
        self.batch, self.max_len = batch_size, max_len
        self.prefill_chunk = prefill_chunk
        self.sampling = sampling or SamplingConfig()
        self.mesh = mesh
        self.buckets = tuple(sorted(prefill_buckets or default_buckets(max_len)))
        assert self.buckets[-1] <= max_len, "bucket exceeds cache capacity"

        self.queue: deque[Request] = deque()
        self.slots: list[Optional[Request]] = [None] * batch_size
        self.cache = None
        # Host-side per-slot decode state: the position the next token will
        # be written at (== tokens cached), and the last sampled token that
        # the next generate step consumes.
        self._positions = np.zeros(batch_size, np.int32)
        self._next_tok = np.zeros(batch_size, np.int32)
        self._done: list[Request] = []
        self._step_idx = 0
        self._prefill_idx = 0
        self._base_key = jax.random.PRNGKey(self.sampling.seed)

        # -- telemetry (repro.obs): engine-scoped registry so concurrent
        # engines (e.g. spec target + vanilla baseline in one bench) never
        # share counters; the tracer defaults to the ambient one, which is
        # the free NullTracer unless a launcher installed a real Tracer.
        self.registry = registry if registry is not None else Registry()
        self.tracer = tracer if tracer is not None else get_tracer()
        self.mfu = MFUMeter(cfg, self.registry)
        self._stat_keys = ["prefill_calls", "insert_calls", "decode_steps"]
        self._counters = {
            k: self.registry.counter(f"serve_{k}_total", h)
            for k, h in [
                ("prefill_calls", "prefill jit invocations"),
                ("insert_calls", "cache-insert jit invocations"),
                ("decode_steps", "batched generate steps"),
            ]
        }
        self._tokens_total = self.registry.counter(
            "serve_tokens_total", "tokens emitted across all requests"
        )
        self._requests_total = self.registry.counter(
            "serve_requests_completed_total", "requests retired"
        )
        self._h_ttft = self.registry.histogram(
            "serve_ttft_seconds", "submit -> first token"
        )
        self._h_tpot = self.registry.histogram(
            "serve_tpot_seconds", "per-token latency of batched decode steps"
        )
        self._h_queue = self.registry.histogram(
            "serve_queue_wait_seconds", "submit -> prefill start"
        )
        self._h_prefill = self.registry.histogram(
            "serve_prefill_seconds", "prefill + insert wall time"
        )
        self._h_batch_util = self.registry.histogram(
            "serve_batch_utilization", "live slots / batch per generate step",
            buckets=tuple(np.round(np.arange(0.05, 1.05, 0.05), 2)),
        )
        self._g_occupancy = self.registry.gauge(
            "serve_slot_occupancy", "fraction of decode slots live"
        )
        self._g_queue_depth = self.registry.gauge(
            "serve_queue_depth", "requests waiting for a slot"
        )
        self._g_compiled = self.registry.gauge(
            "serve_jit_executables", "compiled executables per engine phase",
            ("phase",),
        )

        # -- speculative decoding (repro.spec): draft worker + verify jit --
        self.spec = spec
        self.draft = None
        if spec is not None:
            # Imported lazily: repro.spec pulls in repro.serve.serve_step,
            # so a module-level import here would be circular.
            from repro.spec import DraftWorker, make_spec_verify, resolve_draft_config

            if not self.sampling.greedy:
                raise ValueError(
                    "speculative decoding requires greedy sampling "
                    "(lossless greedy acceptance)"
                )
            self.draft_cfg = resolve_draft_config(spec, cfg)
            if draft_params is None:
                if spec.draft_arch is not None:
                    raise ValueError(
                        "draft_params is required when draft_arch names a "
                        "distinct model"
                    )
                draft_params = params  # self-draft
            self.draft = DraftWorker(
                self.draft_cfg, draft_params,
                batch_size=batch_size, max_len=max_len,
                prefill_chunk=prefill_chunk,
            )
            self._verify_jit = jax.jit(make_spec_verify(cfg))
            spec_keys = [
                ("verify_steps", "wide verify forwards"),
                ("draft_steps", "draft decode steps"),
                ("proposed_tokens", "draft tokens proposed"),
                ("accepted_tokens", "draft tokens the target accepted"),
            ]
            self._stat_keys += [k for k, _ in spec_keys]
            self._counters.update(
                {k: self.registry.counter(f"serve_{k}_total", h)
                 for k, h in spec_keys}
            )
            self._g_acceptance = self.registry.gauge(
                "spec_acceptance_rate",
                "cumulative fraction of proposed draft tokens accepted",
            )

        scfg = self.sampling

        def _prefill(params, tokens, true_len, key):
            # tokens [1, bucket]; a fresh single-request cache sized to the
            # bucket (not max_len) keeps prefill memory and the insert copy
            # proportional to the prompt, MaxText-style.
            bucket = tokens.shape[1]
            cache = init_cache(cfg, 1, bucket)
            logits, cache = prefill_step(
                params, cfg, tokens, cache,
                jnp.reshape(true_len, (1,)),
                chunk_size=self.prefill_chunk,
            )
            last = jnp.take(logits[0], true_len - 1, axis=0)  # [V]
            return sample_logits(last, key, scfg), cache

        # One jitted callable each; distinct buckets become distinct cache
        # entries of the same executable family (``_cache_size()`` counts
        # them — the recompile tests pin it to the bucket count).
        def _insert(cache, prefix, slot):
            # Closure (not `jax.jit(insert_cache)` directly): pjit caches on
            # function identity, so jitting the shared module-level function
            # would pool executables across engines and make per-engine
            # compile_counts() meaningless.
            return insert_cache(cache, prefix, slot)

        self._prefill_jit = jax.jit(_prefill)
        self._insert_jit = jax.jit(_insert)
        self._decode_jit = jax.jit(make_decode_step(cfg, sampling=scfg))

    # -- introspection ------------------------------------------------------

    @property
    def stats(self) -> dict:
        """Legacy raw-counter view, now backed by the ``repro.obs``
        registry (``serve_*_total`` counters).  Returns a fresh plain dict
        each access, so ``dict(engine.stats)`` / delta-subtraction idioms
        from existing tests and benchmarks keep working."""
        return {k: int(self._counters[k].value) for k in self._stat_keys}

    def compile_counts(self) -> dict:
        """Executables compiled so far, per phase (also exported as the
        ``serve_jit_executables`` gauge)."""
        counts = {
            "prefill": self._prefill_jit._cache_size(),
            "insert": self._insert_jit._cache_size(),
            "generate": self._decode_jit._cache_size(),
        }
        if self.draft is not None:
            counts["verify"] = self._verify_jit._cache_size()
            counts.update(self.draft.compile_counts())
        for phase, n in counts.items():
            self._g_compiled.labels(phase=phase).set(n)
        return counts

    def acceptance_rate(self) -> float:
        """Fraction of proposed draft tokens the target accepted."""
        proposed = self._counters["proposed_tokens"].value if self.draft else 0
        return self._counters["accepted_tokens"].value / proposed if proposed else 0.0

    # -- request intake -----------------------------------------------------

    def submit(self, req: Request) -> None:
        if len(req.prompt) > self.buckets[-1]:
            raise ValueError(
                f"prompt length {len(req.prompt)} exceeds the largest prefill "
                f"bucket {self.buckets[-1]}"
            )
        req.t_submit = time.perf_counter()
        self.queue.append(req)
        self._g_queue_depth.set(len(self.queue))

    def _bucket_for(self, plen: int) -> int:
        for b in self.buckets:
            if plen <= b:
                return b
        raise ValueError(plen)  # unreachable: submit() validates

    # -- engine phases ------------------------------------------------------

    def _mesh_ctx(self):
        return jax.set_mesh(self.mesh) if self.mesh is not None else contextlib.nullcontext()

    def _ensure_cache(self) -> None:
        if self.cache is not None:
            return
        with self._mesh_ctx():
            cache = init_cache(self.cfg, self.batch, self.max_len)
        if self.mesh is not None:
            from repro.dist.sharding import cache_shardings

            cache = jax.device_put(
                cache, cache_shardings(cache, self.cfg, self.mesh)
            )
        self.cache = cache

    def _prefill_into_slot(self, req: Request, slot: int) -> int:
        """Prefill ``req`` (one jit call) and insert it into ``slot``."""
        plen = len(req.prompt)
        bucket = self._bucket_for(plen)
        toks = np.zeros((1, bucket), np.int32)
        toks[0, :plen] = req.prompt
        key = jax.random.fold_in(self._base_key, self._prefill_idx)
        self._prefill_idx += 1
        req.t_prefill = t0 = time.perf_counter()
        with self._mesh_ctx(), self.tracer.span(
            "prefill", cat="serve", tid=slot,
            args={"rid": req.rid, "len": plen, "bucket": bucket},
        ):
            tok0, prefix = self._prefill_jit(
                self.params, jnp.asarray(toks), jnp.asarray(plen, jnp.int32), key
            )
            self.cache = self._insert_jit(
                self.cache, prefix, jnp.asarray(slot, jnp.int32)
            )
            tok0 = int(tok0)  # blocks: the first token is now on the host
        # The first token is sampled inside prefill, so TTFT == queue wait
        # plus the prefill span.
        req.t_first_token = req.t_last_token = now = time.perf_counter()
        self._counters["prefill_calls"].inc()
        self._counters["insert_calls"].inc()
        self._tokens_total.inc()
        self._h_prefill.observe(now - t0)
        self._h_queue.observe(t0 - req.t_submit)
        self._h_ttft.observe(now - req.t_submit)
        self.mfu.prefill(plen, now - t0)
        self._g_queue_depth.set(len(self.queue))
        self._positions[slot] = plen
        self._next_tok[slot] = tok0
        return tok0

    def _retire(self, slot: int) -> None:
        req = self.slots[slot]
        req.done = True
        self._done.append(req)
        self.slots[slot] = None
        self._finish(req, slot)

    def _finish(self, req: Request, slot: int) -> None:
        """Close out a request's telemetry: completion counter plus the
        retroactive per-request lifecycle spans (queue-wait -> prefill ->
        decode) on the slot's trace lane."""
        self._requests_total.inc()
        tr = self.tracer
        if req.t_submit is not None and req.t_prefill is not None:
            tr.complete_abs(
                "queued", req.t_submit, req.t_prefill, cat="request",
                tid=slot, args={"rid": req.rid},
            )
        if req.t_first_token is not None and req.t_last_token is not None:
            n = len(req.output)
            tr.complete_abs(
                "decode", req.t_first_token, req.t_last_token, cat="request",
                tid=slot, args={"rid": req.rid, "tokens": n},
            )
            tr.instant("retire", tid=slot, args={"rid": req.rid, "tokens": n})

    def step(self) -> bool:
        """Back-fill free slots, then advance every live slot one token.

        Returns True while work remains (live slots or queued requests).
        """
        self._ensure_cache()
        # Insert phase: fill every free slot from the queue.  A request
        # that completes at prefill (max_new_tokens == 1 or immediate EOS)
        # retires without occupying the slot.
        for i in range(self.batch):
            while self.slots[i] is None and self.queue:
                req = self.queue.popleft()
                tok0 = self._prefill_into_slot(req, i)
                req.output.append(tok0)
                if tok0 == req.eos_id or req.max_new_tokens <= 1:
                    req.done = True
                    self._done.append(req)
                    self._finish(req, i)
                else:
                    self.slots[i] = req
                    if self.draft is not None:
                        # Mirror the insert into the draft's slot pool so
                        # its context matches the target's from round one.
                        self.draft.prefill_into_slot(
                            req.prompt, i, self._bucket_for(len(req.prompt))
                        )

        live = [i for i in range(self.batch) if self.slots[i] is not None]
        self._g_occupancy.set(len(live) / self.batch)
        self._g_queue_depth.set(len(self.queue))
        if not live:
            return bool(self.queue)
        self._h_batch_util.observe(len(live) / self.batch)

        if self.draft is not None:
            self._spec_generate(live)
        else:
            self._generate(live)
        return bool(self.queue or any(r is not None for r in self.slots))

    def _generate(self, live: list) -> None:
        """Vanilla generate: one batched decode step, one token per slot."""
        args = (
            self.params,
            self.cache,
            jnp.asarray(self._next_tok[:, None]),
            jnp.asarray(self._positions),
        )
        t0 = time.perf_counter()
        with self._mesh_ctx(), self.tracer.span(
            "generate", cat="serve", tid=0,
            args={"live": len(live), "step": self._step_idx},
        ):
            if self.sampling.greedy:
                nt, _logits, self.cache = self._decode_jit(*args)
            else:
                key = jax.random.fold_in(self._base_key, 2**20 + self._step_idx)
                nt, _logits, self.cache = self._decode_jit(*args, key)
            nt = np.asarray(nt)[:, 0]  # blocks on the decode result
        now = time.perf_counter()
        self._counters["decode_steps"].inc()
        self._tokens_total.inc(len(live))
        # One batched step emits one token per live slot, so the step wall
        # time *is* each slot's per-token latency this round.
        self._h_tpot.observe(now - t0)
        self.mfu.decode(self._positions[live], now - t0)
        self._step_idx += 1

        self._positions[live] += 1
        for i in live:
            req = self.slots[i]
            req.t_last_token = now
            tok = int(nt[i])
            req.output.append(tok)
            if (
                tok == req.eos_id
                or len(req.output) >= req.max_new_tokens
                or self._positions[i] >= self.max_len  # cache slot exhausted
            ):
                self._retire(i)
            else:
                self._next_tok[i] = tok

    def _spec_generate(self, live: list) -> None:
        """Speculative generate: K draft steps + one wide verify pass.

        Emits between 1 and K+1 tokens per live slot per round.  The
        emitted tokens are always the target's own greedy continuation
        (``repro.spec.verify``), so the output stream is token-identical
        to ``_generate``'s — speculation changes step count, never tokens.
        """
        k = self.spec.lookahead
        t0 = time.perf_counter()
        with self.tracer.span("draft", cat="serve", tid=0, args={"k": k}):
            drafts = self.draft.propose(self._next_tok, k)  # [B, K]
        tokens = np.concatenate(
            [self._next_tok[:, None], drafts], axis=1
        ).astype(np.int32)
        t1 = time.perf_counter()
        with self._mesh_ctx(), self.tracer.span(
            "verify", cat="serve", tid=0, args={"live": len(live), "k": k}
        ):
            greedy, accepted, self.cache = self._verify_jit(
                self.params, self.cache,
                jnp.asarray(tokens), jnp.asarray(self._positions),
            )
            greedy, accepted = np.asarray(greedy), np.asarray(accepted)
        now = time.perf_counter()
        self._counters["verify_steps"].inc()
        self._counters["draft_steps"].inc(k + 1)
        self.mfu.verify(self._positions[live], k, now - t1)
        # Per-token latency of the round: the full draft+verify wall time
        # amortized over the tokens it emitted (upper bound: early
        # retirement can drop a few of them).
        emitted = int(np.sum(accepted[live] + 1))
        self._h_tpot.observe((now - t0) / max(emitted, 1))
        self._step_idx += 1

        # Post-verify lengths (the in-jit rollback already clamped
        # ``accepted`` to cache capacity); the draft mirrors them so both
        # caches hold exactly the accepted prefix next round.
        new_lengths = self._positions + accepted + 1

        for i in live:
            req = self.slots[i]
            req.t_last_token = now
            pos0 = int(self._positions[i])
            n = int(accepted[i])
            self._counters["proposed_tokens"].inc(k)
            self._counters["accepted_tokens"].inc(n)
            # Consume the emitted run token by token, applying the same
            # retirement rules (EOS / max_new_tokens / capacity) at the
            # same points vanilla decode would.
            for j in range(n + 1):
                tok = int(greedy[i, j])
                req.output.append(tok)
                self._tokens_total.inc()
                self._positions[i] = pos0 + j + 1
                if (
                    tok == req.eos_id
                    or len(req.output) >= req.max_new_tokens
                    or pos0 + j + 1 >= self.max_len
                ):
                    self._retire(i)
                    break
            else:
                self._next_tok[i] = int(greedy[i, n])
        self._g_acceptance.set(self.acceptance_rate())
        self.draft.rollback(new_lengths)

    def run(self, max_steps: int = 100_000) -> list[Request]:
        """Drain the queue; returns completed requests."""
        steps = 0
        while steps < max_steps:
            steps += 1
            if not self.step():
                break
        self.compile_counts()  # refresh the serve_jit_executables gauges
        done, self._done = self._done, []
        return done


def sequential_greedy_decode(
    cfg: ModelConfig,
    params,
    prompt: np.ndarray,
    max_new_tokens: int,
    *,
    eos_id: int = -1,
    max_len: Optional[int] = None,
) -> list[int]:
    """Obviously-correct single-request baseline: teacher-forced per-token
    prefill plus greedy decode, batch 1, one jit dispatch per token.  The
    engine's token-equivalence harness checks continuous batching against
    exactly this."""
    plen = len(prompt)
    max_len = max_len or plen + max_new_tokens
    cache = init_cache(cfg, 1, max_len)
    logits = None
    for i in range(plen):
        t = jnp.asarray([[int(prompt[i])]], jnp.int32)
        logits, cache = decode_step(params, cfg, t, cache, jnp.asarray(i, jnp.int32))
    out = [int(jnp.argmax(logits[0, -1]))]
    pos = plen
    while len(out) < max_new_tokens and out[-1] != eos_id and pos < max_len:
        t = jnp.asarray([[out[-1]]], jnp.int32)
        logits, cache = decode_step(params, cfg, t, cache, jnp.asarray(pos, jnp.int32))
        out.append(int(jnp.argmax(logits[0, -1])))
        pos += 1
    return out
