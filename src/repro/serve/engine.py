"""Batched serving engine: continuous-batching-lite request scheduler over
prefill + decode steps.

Requests arrive with prompts of varying length; the engine right-pads into
a fixed batch, prefills once (via the FSA/flash path — the compute-bound
phase the paper targets), then decodes token-by-token with the KV/state
cache, retiring requests at EOS/max_tokens and back-filling free slots from
the queue.  All steps are jit-compiled once per (batch, max_len) bucket.
"""

from __future__ import annotations

import dataclasses
from collections import deque
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.models import decode_step, init_cache


@dataclasses.dataclass(eq=False)
class Request:
    # eq=False: the generated __eq__ would compare the ndarray `prompt`
    # field, making `r in wave` membership raise ("truth value of an array
    # is ambiguous") for distinct same-length prompts.  Requests are
    # identity-equal; `rid` is the stable external key.
    rid: int
    prompt: np.ndarray  # [len] int32
    max_new_tokens: int = 16
    eos_id: int = -1  # -1: never
    output: list = dataclasses.field(default_factory=list)
    done: bool = False


class ServeEngine:
    """Static-batch engine with slot back-filling (single-host)."""

    def __init__(self, cfg: ModelConfig, params, *, batch_size: int = 4,
                 max_len: int = 256):
        assert cfg.family != "encoder", "encoder archs have no decode phase"
        self.cfg, self.params = cfg, params
        self.batch, self.max_len = batch_size, max_len
        self.queue: deque[Request] = deque()
        self.slots: list[Optional[Request]] = [None] * batch_size
        # Per-run jit-invocation counters (regression-tested: prefill must
        # cost exactly prompt_len decode steps per wave, not prompt_len
        # steps *plus* a full batched forward).
        self.stats = {"decode_steps": 0}

        self._decode = jax.jit(
            lambda p, t, c, pos: decode_step(p, cfg, t, c, pos)
        )

    def submit(self, req: Request) -> None:
        self.queue.append(req)

    def run(self, max_steps: int = 1024) -> list[Request]:
        """Drain the queue; returns completed requests."""
        done: list[Request] = []
        # NOTE single shared cache across slots: per-slot positions differ,
        # so this simple engine admits one prompt length per wave.
        while (self.queue or any(self.slots)) and max_steps > 0:
            max_steps -= 1
            # Fill free slots (one wave shares a prompt length).
            for i in range(self.batch):
                if self.slots[i] is None and self.queue:
                    self.slots[i] = self.queue.popleft()
            live = [r for r in self.slots if r is not None]
            if not live:
                break
            plen = len(live[0].prompt)
            wave = [r for r in live if len(r.prompt) == plen]

            toks = np.zeros((self.batch, plen), np.int32)
            for i, r in enumerate(self.slots):
                if r in wave:
                    toks[i, :] = r.prompt
            # Teacher-forced prefill: one decode step per prompt position
            # (family-agnostic: fills KV caches and SSM states alike).  The
            # final step's logits *are* the prefill logits at plen-1, so the
            # first token is sampled from them directly — the old engine
            # additionally ran a full batched forward over the prompt and
            # then discarded the step-wise logits, prefilling twice.
            self.cache = init_cache(self.cfg, self.batch, self.max_len)
            for pos in range(plen):
                t = jnp.asarray(toks[:, pos : pos + 1])
                logits, self.cache = self._decode(
                    self.params, t, self.cache, jnp.asarray(pos, jnp.int32)
                )
                self.stats["decode_steps"] += 1
            next_tok = np.asarray(jnp.argmax(logits[:, -1, :], axis=-1), np.int32)

            # Decode until every wave member finishes.
            pos = plen
            active = {id(r) for r in wave}
            while active and pos < self.max_len:
                t = jnp.asarray(next_tok[:, None])
                logits_d, self.cache = self._decode(
                    self.params, t, self.cache, jnp.asarray(pos, jnp.int32)
                )
                self.stats["decode_steps"] += 1
                for i, r in enumerate(self.slots):
                    if r in wave and not r.done:
                        tok = int(next_tok[i])
                        r.output.append(tok)
                        if tok == r.eos_id or len(r.output) >= r.max_new_tokens:
                            r.done = True
                            active.discard(id(r))
                next_tok = np.asarray(
                    jnp.argmax(logits_d[:, -1, :], axis=-1), np.int32
                )
                pos += 1
            for i, r in enumerate(self.slots):
                if r in wave:
                    r.done = True
                    done.append(r)
                    self.slots[i] = None
        return done
