"""Serving steps: prefill (full-sequence forward) and decode (one token
against the KV/state caches).  Per the paper §8.3 the FSA/flash path is used
for prefill only; decode is the memory-bound einsum path.
"""

from __future__ import annotations

from typing import Any, Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models.model import decode_step, forward


def make_prefill_step(cfg: ModelConfig):
    def prefill_step(params, batch):
        logits = forward(
            params,
            cfg,
            tokens=batch.get("tokens"),
            embeds=batch.get("embeds"),
            positions=batch.get("positions"),
        )
        # Return only the last-position logits (what serving samples from);
        # keeps the output payload O(B x V) instead of O(B x S x V).
        return logits[:, -1, :]

    return prefill_step


def make_decode_step(cfg: ModelConfig, *, greedy: bool = True):
    def serve_step(params, cache, tokens, position):
        logits, new_cache = decode_step(params, cfg, tokens, cache, position)
        next_tok = jnp.argmax(logits[:, -1, :], axis=-1).astype(jnp.int32)
        return next_tok[:, None], logits, new_cache

    return serve_step
