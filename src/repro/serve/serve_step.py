"""Serving steps: prefill (full-sequence forward) and decode (one token
against the KV/state caches), plus the sampling policies the engine threads
through both.  Per the paper §8.3 the FSA/flash path is used for prefill
only; decode is the memory-bound einsum path.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models.model import decode_step, forward


@dataclasses.dataclass(frozen=True)
class SamplingConfig:
    """Sampling policy, applied in order: temperature -> top-k -> top-p.

    ``temperature == 0`` means greedy argmax (top_k/top_p ignored); the
    fields are static jit constants, so changing the policy recompiles the
    decode step once rather than threading runtime branches through it.
    """

    temperature: float = 0.0
    top_k: int = 0  # 0: no top-k truncation
    top_p: float = 1.0  # 1.0: no nucleus truncation
    seed: int = 0

    @property
    def greedy(self) -> bool:
        return self.temperature <= 0.0


def sample_logits(
    logits: jax.Array,  # [..., V]
    key: Optional[jax.Array],
    scfg: SamplingConfig,
) -> jax.Array:
    """Sample token ids from logits under the configured policy."""
    logits = logits.astype(jnp.float32)
    if scfg.greedy:
        return jnp.argmax(logits, axis=-1).astype(jnp.int32)
    logits = logits / scfg.temperature
    if scfg.top_k > 0:
        kth = jax.lax.top_k(logits, scfg.top_k)[0][..., -1:]
        logits = jnp.where(logits < kth, -jnp.inf, logits)
    if scfg.top_p < 1.0:
        sorted_desc = jnp.flip(jnp.sort(logits, axis=-1), axis=-1)
        cum = jnp.cumsum(jax.nn.softmax(sorted_desc, axis=-1), axis=-1)
        # Keep the smallest prefix whose mass reaches top_p (the argmax
        # token always survives: its cum-prob term starts the prefix).
        keep = cum - jax.nn.softmax(sorted_desc, axis=-1) < scfg.top_p
        kth = jnp.min(
            jnp.where(keep, sorted_desc, jnp.inf), axis=-1, keepdims=True
        )
        logits = jnp.where(logits < kth, -jnp.inf, logits)
    return jax.random.categorical(key, logits, axis=-1).astype(jnp.int32)


def make_prefill_step(cfg: ModelConfig):
    def prefill_step(params, batch):
        logits = forward(
            params,
            cfg,
            tokens=batch.get("tokens"),
            embeds=batch.get("embeds"),
            positions=batch.get("positions"),
        )
        # Return only the last-position logits (what serving samples from);
        # keeps the output payload O(B x V) instead of O(B x S x V).
        return logits[:, -1, :]

    return prefill_step


def make_decode_step(cfg: ModelConfig, *, sampling: Optional[SamplingConfig] = None):
    """Decode step closure.  Greedy (``sampling`` None or temperature 0)
    keeps the 4-arg ``(params, cache, tokens, position)`` contract the
    launch/dry-run cells lower; a stochastic policy appends a PRNG ``key``
    argument."""
    scfg = sampling or SamplingConfig()

    if scfg.greedy:

        def serve_step(params, cache, tokens, position):
            logits, new_cache = decode_step(params, cfg, tokens, cache, position)
            next_tok = sample_logits(logits[:, -1, :], None, scfg)
            return next_tok[:, None], logits, new_cache

        return serve_step

    def serve_step_sampled(params, cache, tokens, position, key):
        logits, new_cache = decode_step(params, cfg, tokens, cache, position)
        next_tok = sample_logits(logits[:, -1, :], key, scfg)
        return next_tok[:, None], logits, new_cache

    return serve_step_sampled
