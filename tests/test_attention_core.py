"""SystolicAttention (Algorithm 1, jnp) vs the materialized-softmax oracle."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core.attention import naive_attention, systolic_attention

CASES = [
    # (B, Sq, Sk, H, Hkv, d, causal, bq, bk)
    (2, 256, 256, 4, 2, 64, True, 128, 128),
    (1, 128, 384, 4, 4, 32, False, 64, 64),
    (2, 100, 200, 6, 3, 48, True, 64, 64),
    (1, 1, 333, 8, 4, 128, True, 128, 128),
    (2, 77, 77, 4, 1, 128, False, 32, 64),
]


def _rand(case, key=0):
    b, sq, sk, h, hkv, d, causal, bq, bk = case
    ks = jax.random.split(jax.random.PRNGKey(key), 3)
    q = jax.random.normal(ks[0], (b, sq, h, d), jnp.float32)
    k = jax.random.normal(ks[1], (b, sk, hkv, d), jnp.float32)
    v = jax.random.normal(ks[2], (b, sk, hkv, d), jnp.float32)
    return q, k, v


@pytest.mark.parametrize("case", CASES)
def test_matches_oracle_exact_exp2(case):
    b, sq, sk, h, hkv, d, causal, bq, bk = case
    q, k, v = _rand(case)
    qo = sk - sq if causal else 0
    ref = naive_attention(q, k, v, causal=causal, q_offset=qo)
    out = systolic_attention(
        q, k, v, causal=causal, q_offset=qo, block_q=bq, block_k=bk
    )
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=2e-5)


@pytest.mark.parametrize("case", CASES[:3])
def test_pwl_within_paper_error_envelope(case):
    """Table 2: PWL exp2 end-to-end attention MAE stays in the 1e-3 range."""
    b, sq, sk, h, hkv, d, causal, bq, bk = case
    q, k, v = _rand(case)
    qo = sk - sq if causal else 0
    ref = naive_attention(q, k, v, causal=causal, q_offset=qo)
    out = systolic_attention(
        q, k, v, causal=causal, q_offset=qo, block_q=bq, block_k=bk,
        exp2_impl="pwl",
    )
    mae = float(jnp.abs(out - ref).mean())
    assert mae < 5e-3


def test_block_size_invariance():
    """Property: output independent of tiling (the online-softmax invariant)."""
    case = (1, 192, 192, 2, 2, 32, True, 0, 0)
    q, k, v = _rand(case)
    outs = [
        systolic_attention(q, k, v, causal=True, block_q=bq, block_k=bk)
        for bq, bk in ((32, 32), (64, 48), (192, 192), (192, 64))
    ]
    for o in outs[1:]:
        np.testing.assert_allclose(np.asarray(o), np.asarray(outs[0]), atol=2e-5)


def test_unroll_invariance():
    case = (1, 128, 128, 2, 1, 32, True, 0, 0)
    q, k, v = _rand(case)
    a = systolic_attention(q, k, v, causal=True, block_q=64, block_k=64)
    b = systolic_attention(q, k, v, causal=True, block_q=64, block_k=64, unroll=True)
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=1e-6)


@settings(max_examples=20, deadline=None)
@given(
    st.integers(min_value=1, max_value=3),
    st.integers(min_value=8, max_value=96),
    st.integers(min_value=1, max_value=4),
    st.booleans(),
)
def test_property_random_shapes(b, s, h, causal):
    d = 16
    q, k, v = _rand((b, s, s, h, h, d, causal, 0, 0), key=s)
    ref = naive_attention(q, k, v, causal=causal)
    out = systolic_attention(q, k, v, causal=causal, block_q=32, block_k=32)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=3e-5)


def test_rows_fully_masked_are_finite():
    """Decode-style q at position 0 with causal mask: no NaNs from 0/0."""
    q, k, v = _rand((1, 4, 4, 1, 1, 8, True, 0, 0))
    out = systolic_attention(q, k, v, causal=True, block_q=2, block_k=2)
    assert bool(jnp.isfinite(out).all())


def test_grad_flows():
    q, k, v = _rand((1, 64, 64, 2, 2, 16, True, 0, 0))

    def loss(q, k, v):
        return systolic_attention(q, k, v, causal=True, block_q=32, block_k=32).sum()

    g = jax.grad(loss, argnums=(0, 1, 2))(q, k, v)
    for gi in g:
        assert bool(jnp.isfinite(gi).all())
