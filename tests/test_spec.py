"""Speculative decoding (repro.spec): lossless-greedy equivalence and
cache-rollback invariants.

The contract under test extends the ServeEngine token-equivalence harness
(test_serve_engine.py): a speculative engine — draft proposes K tokens,
target verifies all of them in one wide forward, rejected suffix rolls
back — must emit exactly the tokens the vanilla engine emits, request for
request, under greedy sampling.  This is structural: accepted draft tokens
equal the target's own greedy argmax by construction, so acceptance only
changes how many steps it takes, never which tokens come out.

Pinned here:
  * spec == vanilla bit-identical on dense and MoE families (the MoE case
    needs dropless decode routing — capacity-bounded routing made a
    token's experts depend on its lane-mates);
  * ditto with an int8-quantized draft (acceptance drops, outputs don't);
  * self-draft acceptance is exactly 1.0 and verify steps ~ tokens/(K+1);
  * KV rollback via lengths truncation: verify writes beyond the accepted
    prefix are dead (never read, overwritten in place);
  * the draft cache mirrors the target slot lifecycle across eviction and
    back-fill, including a *longer* prompt re-using an evicted slot;
  * compile stability: one verify + one draft-generate executable, reused
    across waves and mixed prefill buckets.
"""

import os

if "xla_force_host_platform_device_count" not in os.environ.get("XLA_FLAGS", ""):
    os.environ["XLA_FLAGS"] = (
        os.environ.get("XLA_FLAGS", "") + " --xla_force_host_platform_device_count=8"
    )

import jax  # noqa: E402
import jax.numpy as jnp  # noqa: E402
import numpy as np  # noqa: E402
import pytest  # noqa: E402

from repro.configs import get_smoke_config  # noqa: E402
from repro.configs.base import ModelConfig, MoEConfig  # noqa: E402
from repro.models import (  # noqa: E402
    decode_step,
    init_cache,
    init_params,
    rollback_cache,
    verify_step,
)
from repro.serve import Request, SamplingConfig, ServeEngine  # noqa: E402
from repro.spec import SpecConfig, resolve_draft_config  # noqa: E402

TINY = ModelConfig(
    name="tiny",
    family="dense",
    num_layers=2,
    d_model=64,
    num_heads=4,
    num_kv_heads=2,
    head_dim=16,
    d_ff=128,
    vocab_size=128,
    mlp_type="swiglu",
    dtype="float32",
    remat=False,
)

TINY_MOE = ModelConfig(
    name="tiny-moe",
    family="moe",
    num_layers=2,
    d_model=64,
    num_heads=4,
    num_kv_heads=2,
    head_dim=16,
    d_ff=128,
    vocab_size=128,
    mlp_type="swiglu",
    dtype="float32",
    remat=False,
    moe=MoEConfig(num_experts=4, top_k=2, d_ff_expert=64, capacity_factor=1.25),
)

MAX_LEN = 64


@pytest.fixture(scope="module")
def params():
    return init_params(TINY, jax.random.PRNGKey(0))


@pytest.fixture(scope="module")
def moe_params_fx():
    return init_params(TINY_MOE, jax.random.PRNGKey(0))


def _prompts(cfg, spec, seed=0):
    rng = np.random.default_rng(seed)
    return [
        rng.integers(1, cfg.vocab_size, size=plen).astype(np.int32)
        for plen, _ in spec
    ]


def _run(cfg, params, prompts, spec, *, spec_cfg=None, draft_params=None,
         batch=2, buckets=(8, 16, 32), chunk=None):
    eng = ServeEngine(
        cfg, params, batch_size=batch, max_len=MAX_LEN,
        prefill_chunk=chunk, prefill_buckets=buckets,
        spec=spec_cfg, draft_params=draft_params,
    )
    for i, p in enumerate(prompts):
        eng.submit(Request(rid=i, prompt=p, max_new_tokens=spec[i][1]))
    done = eng.run()
    return eng, {r.rid: r.output for r in done}


# -- lossless token equivalence, vanilla vs speculative -----------------------

SCHEDULE = [(5, 6), (13, 4), (24, 5), (9, 3), (17, 6)]  # > slots: evict+refill


@pytest.mark.parametrize(
    "cfg_name,lookahead", [("dense", 4), ("dense", 1), ("dense", 7), ("moe", 4)]
)
def test_spec_greedy_matches_vanilla(params, moe_params_fx, cfg_name, lookahead):
    cfg, p = (TINY, params) if cfg_name == "dense" else (TINY_MOE, moe_params_fx)
    prompts = _prompts(cfg, SCHEDULE, seed=3)
    _, ref = _run(cfg, p, prompts, SCHEDULE)
    eng, out = _run(
        cfg, p, prompts, SCHEDULE,
        spec_cfg=SpecConfig(lookahead=lookahead), draft_params=p,
    )
    assert out == ref
    # Self-draft: the draft IS the target, so every proposal matches.
    assert eng.acceptance_rate() == 1.0
    assert eng.stats["verify_steps"] < eng.stats["accepted_tokens"] + len(SCHEDULE)


def test_spec_int8_draft_lossless(params):
    """int8 draft, fp32 target: acceptance may drop below 1.0 but the
    emitted stream stays the target's exact greedy continuation."""
    prompts = _prompts(TINY, SCHEDULE, seed=5)
    _, ref = _run(TINY, params, prompts, SCHEDULE)
    eng, out = _run(
        TINY, params, prompts, SCHEDULE,
        spec_cfg=SpecConfig(lookahead=4, draft_quant="int8"), draft_params=params,
    )
    assert out == ref
    assert 0.0 <= eng.acceptance_rate() <= 1.0


def test_spec_chunked_prefill_matches_vanilla(params):
    """Chunked flash prefill composes with spec mode (both caches fill
    through their own chunk loop)."""
    spec = [(24, 6), (17, 6), (30, 4)]
    prompts = _prompts(TINY, spec, seed=11)
    _, ref = _run(TINY, params, prompts, spec, buckets=(32,))
    _, out = _run(
        TINY, params, prompts, spec, buckets=(32,), chunk=8,
        spec_cfg=SpecConfig(lookahead=3), draft_params=params,
    )
    assert out == ref


def test_spec_distinct_draft_arch_lossless(params):
    """A different (random-init, so near-useless) draft model still yields
    the target's exact greedy tokens — only the acceptance rate suffers."""
    spec_cfg = SpecConfig(draft_arch="olmo-1b", lookahead=3)
    dcfg = resolve_draft_config(spec_cfg, get_smoke_config("olmo-1b"))
    # Draft must share the target's vocab; smoke olmo vocab != TINY's, so
    # run the target as the olmo smoke config itself.
    tcfg = get_smoke_config("olmo-1b")
    tparams = init_params(tcfg, jax.random.PRNGKey(0))
    dparams = init_params(dcfg, jax.random.PRNGKey(1))
    sched = [(5, 5), (9, 4), (3, 5)]
    prompts = _prompts(tcfg, sched, seed=2)
    _, ref = _run(tcfg, tparams, prompts, sched)
    _, out = _run(
        tcfg, tparams, prompts, sched, spec_cfg=spec_cfg, draft_params=dparams,
    )
    assert out == ref


# -- verify/rollback unit invariants ------------------------------------------

def test_verify_step_matches_sequential_decode(params):
    """One [B, S] verify pass produces the same logits as S sequential
    decode steps, and rollback leaves the cache able to continue
    identically."""
    b, s, plen = 2, 4, 6
    rng = np.random.default_rng(0)
    toks = jnp.asarray(rng.integers(1, TINY.vocab_size, (b, plen + s)), jnp.int32)

    # Build a cache by decoding the prompt teacher-forced, one token at a time.
    cache = init_cache(TINY, b, MAX_LEN)
    for j in range(plen):
        _, cache = decode_step(
            params, TINY, toks[:, j][:, None], cache, jnp.full((b,), j)
        )

    seq_logits = []
    seq_cache = cache
    for j in range(s):
        lg, seq_cache = decode_step(
            params, TINY, toks[:, plen + j][:, None], seq_cache,
            jnp.full((b,), plen + j),
        )
        seq_logits.append(lg[:, 0])

    ver_logits, ver_cache = verify_step(
        params, TINY, toks[:, plen:plen + s], cache, jnp.full((b,), plen)
    )
    np.testing.assert_allclose(
        np.asarray(ver_logits), np.stack([np.asarray(x) for x in seq_logits], 1),
        rtol=1e-5, atol=1e-5,
    )

    # Roll back to plen + 2 (accept 1 draft token + bonus) and continue:
    # the continuation must match a cache that never saw the rejected rows.
    ver_cache = rollback_cache(ver_cache, jnp.full((b,), plen + 2, jnp.int32))
    nxt = toks[:, plen + 2][:, None]
    a, _ = decode_step(params, TINY, nxt, ver_cache, jnp.full((b,), plen + 2))
    clean_cache = rollback_cache(  # fully-decoded cache, then truncate
        seq_cache, jnp.full((b,), plen + 2, jnp.int32)
    )
    e, _ = decode_step(params, TINY, nxt, clean_cache, jnp.full((b,), plen + 2))
    np.testing.assert_allclose(np.asarray(a), np.asarray(e), rtol=1e-5, atol=1e-5)


def test_spec_config_validation():
    with pytest.raises(ValueError):
        SpecConfig(lookahead=0)
    with pytest.raises(ValueError):
        SpecConfig(acceptance="topk")
    # Recurrent-state families can't roll back: reject at config resolution.
    spec = SpecConfig(draft_arch="zamba2-1.2b")
    with pytest.raises(ValueError, match="rollback"):
        resolve_draft_config(spec, get_smoke_config("olmo-1b"))
    with pytest.raises(ValueError, match="rollback"):
        resolve_draft_config(SpecConfig(), get_smoke_config("zamba2-1.2b"))


def test_spec_requires_greedy(params):
    with pytest.raises(ValueError, match="greedy"):
        ServeEngine(
            TINY, params, batch_size=2, max_len=MAX_LEN,
            sampling=SamplingConfig(temperature=0.8, seed=1),
            spec=SpecConfig(), draft_params=params,
        )


# -- slot lifecycle + compile stability (with and without spec) ---------------

def _eviction_backfill_longer(cfg, p, spec_cfg):
    """3 requests through 2 slots; the back-fill prompt is *longer* than
    the evicted one (different bucket), forcing a fresh prefill into a
    dirty slot of both caches."""
    sched = [(4, 2), (5, 2), (20, 6)]
    prompts = _prompts(cfg, sched, seed=13)
    _, ref = _run(cfg, p, prompts, sched)
    eng, out = _run(
        cfg, p, prompts, sched,
        spec_cfg=spec_cfg, draft_params=p if spec_cfg else None,
    )
    assert out == ref
    assert eng.stats["prefill_calls"] == 3
    assert eng.batch == 2
    return eng


@pytest.mark.parametrize("mode", ["vanilla", "spec"])
def test_eviction_then_longer_backfill(params, mode):
    spec_cfg = SpecConfig(lookahead=4) if mode == "spec" else None
    _eviction_backfill_longer(TINY, params, spec_cfg)


@pytest.mark.parametrize("mode", ["vanilla", "spec"])
def test_compile_counts_stable_mixed_buckets(params, jit_recompiles, mode):
    """First wave touches every bucket; a second wave of new lengths (same
    buckets) must reuse every executable — including verify and the draft
    pipeline in spec mode."""
    spec_cfg = SpecConfig(lookahead=3) if mode == "spec" else None
    eng = ServeEngine(
        TINY, params, batch_size=2, max_len=MAX_LEN, prefill_buckets=(8, 16),
        spec=spec_cfg, draft_params=params if spec_cfg else None,
    )
    wave1 = [(5, 3), (8, 3), (12, 3), (16, 3)]
    for i, p in enumerate(_prompts(TINY, wave1, seed=1)):
        eng.submit(Request(rid=i, prompt=p, max_new_tokens=3))
    eng.run()
    counts = eng.compile_counts()
    assert counts["prefill"] == 2
    if spec_cfg:
        assert counts["verify"] == 1
        assert counts["draft_generate"] == 1
        assert counts["draft_prefill"] == 2  # same buckets as the target
    else:
        assert counts["generate"] == 1
        assert "verify" not in counts

    jit_recompiles.reset()
    wave2 = [(7, 4), (3, 2), (13, 5), (9, 3)]
    for i, p in enumerate(_prompts(TINY, wave2, seed=2)):
        eng.submit(Request(rid=10 + i, prompt=p, max_new_tokens=wave2[i][1]))
    done = eng.run()
    assert len(done) == 4
    assert jit_recompiles.count == 0, "second wave must reuse all executables"
    assert eng.compile_counts() == counts


# -- Request.prompt coercion (regression) -------------------------------------

def test_request_prompt_list_coerced(params):
    """Request accepts a plain Python list: coerced to int32 ndarray in
    __post_init__, so len()/indexing/np ops inside the engine all work."""
    req = Request(rid=0, prompt=[3, 1, 4, 1, 5], max_new_tokens=2)
    assert isinstance(req.prompt, np.ndarray)
    assert req.prompt.dtype == np.int32
    assert req.prompt.tolist() == [3, 1, 4, 1, 5]

    arr = _prompts(TINY, [(6, 3)], seed=21)[0]
    eng = ServeEngine(TINY, params, batch_size=2, max_len=MAX_LEN,
                      prefill_buckets=(8,))
    eng.submit(Request(rid=0, prompt=arr.tolist(), max_new_tokens=3))
    eng.submit(Request(rid=1, prompt=arr, max_new_tokens=3))
    done = {r.rid: r.output for r in eng.run()}
    assert done[0] == done[1]
