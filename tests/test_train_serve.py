"""Integration tests: trainer loop (checkpoint/restart/preemption), serving
engine, elastic rescale."""

import os

if "xla_force_host_platform_device_count" not in os.environ.get("XLA_FLAGS", ""):
    os.environ["XLA_FLAGS"] = (
        os.environ.get("XLA_FLAGS", "") + " --xla_force_host_platform_device_count=8"
    )

import dataclasses  # noqa: E402

import jax  # noqa: E402
import numpy as np  # noqa: E402
import pytest  # noqa: E402

from repro.configs.base import ModelConfig, ShapeConfig  # noqa: E402
from repro.dist.elastic import apply_rescale, rescale_plan  # noqa: E402
from repro.launch.mesh import make_debug_mesh  # noqa: E402
from repro.models import init_params, param_shapes  # noqa: E402
from repro.optim import AdamW  # noqa: E402
from repro.serve.engine import Request, ServeEngine  # noqa: E402
from repro.train.trainer import Trainer, TrainerConfig  # noqa: E402

TINY = ModelConfig(
    name="tiny",
    family="dense",
    num_layers=2,
    d_model=64,
    num_heads=4,
    num_kv_heads=2,
    head_dim=16,
    d_ff=128,
    vocab_size=128,
    mlp_type="swiglu",
    dtype="float32",
    remat=False,
)
SHAPE = ShapeConfig("t", 32, 4, "train")


def test_trainer_loss_decreases_and_checkpoints(tmp_path):
    tcfg = TrainerConfig(total_steps=12, ckpt_every=5, ckpt_dir=str(tmp_path),
                         peak_lr=1e-3, warmup_steps=2, log_every=100)
    t = Trainer(TINY, SHAPE, tcfg)
    state = t.run()
    assert state["step"] == 12
    assert state["losses"][-1] < state["losses"][0]
    assert t.ckpt.latest_step() == 10


def test_trainer_resumes_from_checkpoint(tmp_path):
    tcfg = TrainerConfig(total_steps=6, ckpt_every=3, ckpt_dir=str(tmp_path),
                         log_every=100)
    state1 = Trainer(TINY, SHAPE, tcfg).run()
    # New trainer, more steps: resumes at 6, continues to 9.
    t2 = Trainer(TINY, SHAPE, dataclasses.replace(tcfg, total_steps=9))
    state2 = t2.run()
    assert state2["step"] == 9
    # Deterministic data: loss sequence continues smoothly (no re-warmup).
    assert np.isfinite(state2["losses"]).all()


def test_trainer_preemption_saves_and_exits(tmp_path):
    tcfg = TrainerConfig(total_steps=50, ckpt_every=100, ckpt_dir=str(tmp_path),
                         log_every=100)
    t = Trainer(TINY, SHAPE, tcfg)
    calls = {"n": 0}

    def on_step(state, metrics):
        calls["n"] += 1
        if calls["n"] == 4:
            t.preempt.trigger()  # simulated SIGTERM

    t.hooks["on_step"] = on_step
    state = t.run()
    assert state["step"] == 4  # drained at the next boundary
    assert t.ckpt.latest_step() == 4  # saved before exit


def test_serve_engine_completes_and_is_deterministic():
    params = init_params(TINY, jax.random.PRNGKey(0))
    rng = np.random.default_rng(0)
    prompts = [rng.integers(0, TINY.vocab_size, size=8).astype(np.int32) for _ in range(5)]

    e1 = ServeEngine(TINY, params, batch_size=2, max_len=32)
    for i, p in enumerate(prompts):
        e1.submit(Request(rid=i, prompt=p, max_new_tokens=4))
    done = e1.run()
    assert len(done) == 5
    assert all(len(r.output) == 4 for r in done)

    e2 = ServeEngine(TINY, params, batch_size=2, max_len=32)
    e2.submit(Request(rid=0, prompt=prompts[0], max_new_tokens=4))
    (again,) = e2.run()
    first = next(r for r in done if r.rid == 0)
    assert again.output == first.output  # batching-invariant greedy decode


def test_serve_prefill_is_single_pass():
    """Regression for the double-prefill bug: the old engine ran a full
    batched forward over the prompt AND then re-filled the cache token by
    token, prefilling twice.  The continuous-batching engine must cost
    exactly one prefill call (the whole prompt in one jit dispatch, K/V
    written in-kernel), one cache insert, and N-1 decode steps for N new
    tokens (the first token comes out of prefill itself)."""
    params = init_params(TINY, jax.random.PRNGKey(0))
    e = ServeEngine(TINY, params, batch_size=2, max_len=32)
    prompt = np.arange(8, dtype=np.int32)
    e.submit(Request(rid=0, prompt=prompt, max_new_tokens=4))
    (r,) = e.run()
    assert len(r.output) == 4
    assert e.stats["prefill_calls"] == 1
    assert e.stats["insert_calls"] == 1
    assert e.stats["decode_steps"] == 4 - 1


@pytest.mark.skipif(jax.device_count() < 8, reason="needs 8 devices")
def test_elastic_rescale_between_meshes(tmp_path):
    """Save on a 2x4 mesh, resume on 4x2 — shardings re-derived, state
    re-placed, training continues."""
    from repro.checkpoint import CheckpointManager

    params = init_params(TINY, jax.random.PRNGKey(0))
    opt = AdamW(lr=1e-3)
    opt_state = opt.init(params)
    mgr = CheckpointManager(str(tmp_path))
    mgr.save(1, {"params": params, "opt": opt_state})

    new_mesh = make_debug_mesh(4, 2)
    pshapes = param_shapes(TINY)
    oshapes = jax.eval_shape(opt.init, pshapes)
    plan = rescale_plan(TINY, pshapes, oshapes, new_mesh, old_devices=8)
    assert plan.new_devices == 8

    template = {
        "params": pshapes,
        "opt": oshapes,
    }
    restored = mgr.restore(1, template)
    placed = apply_rescale(
        restored, {"params": plan.param_shardings, "opt": plan.opt_shardings}
    )
    # One more step on the new mesh.
    import jax.numpy as jnp
    from repro.models import lm_loss

    batch = {
        "tokens": jnp.zeros((4, 8), jnp.int32),
        "labels": jnp.zeros((4, 8), jnp.int32),
    }
    with jax.set_mesh(new_mesh):
        loss, grads = jax.jit(jax.value_and_grad(lambda p: lm_loss(p, TINY, batch)))(
            placed["params"]
        )
        new_params, _ = opt.update(grads, placed["opt"], placed["params"])
    assert bool(jnp.isfinite(loss))


def test_elastic_rescale_rejects_bad_divisibility():
    from repro.configs import get_config

    cfg = get_config("qwen3-moe-235b-a22b")
    mesh = make_debug_mesh(2, 3)  # 128 experts % 3 != 0
    with pytest.raises(ValueError):
        rescale_plan(cfg, {}, {}, mesh, old_devices=8)

def test_microbatched_grads_match_full_batch():
    """Gradient accumulation (num_microbatches=2) == single-batch grads."""
    import jax.numpy as jnp

    from repro.train.train_step import make_train_step

    params = init_params(TINY, jax.random.PRNGKey(0))
    opt = AdamW(lr=0.0, weight_decay=0.0)  # lr=0: isolate the grad metrics
    rng = np.random.default_rng(0)
    batch = {
        "tokens": jax.numpy.asarray(rng.integers(0, TINY.vocab_size, (4, 16)), jnp.int32),
        "labels": jax.numpy.asarray(rng.integers(0, TINY.vocab_size, (4, 16)), jnp.int32),
    }
    s1 = make_train_step(TINY, opt, num_microbatches=1)
    s2 = make_train_step(TINY, opt, num_microbatches=2)
    _, _, m1 = s1(params, opt.init(params), batch)
    _, _, m2 = s2(params, opt.init(params), batch)
    # Same mean loss; grad norms agree to accumulation-order tolerance.
    assert abs(float(m1["loss"]) - float(m2["loss"])) < 1e-4
    np.testing.assert_allclose(
        float(m1["grad_norm"]), float(m2["grad_norm"]), rtol=2e-3
    )
