"""Substrate tests: optimizers, schedules, data pipeline determinism,
checkpoint atomicity/elasticity, fault tolerance, gradient compression."""

import os
import threading

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.checkpoint import CheckpointManager
from repro.configs import SHAPES, get_smoke_config
from repro.data import DataConfig, PrefetchIterator, SyntheticLM, make_source
from repro.dist.fault import (
    PreemptionHandler,
    StepWatchdog,
    StragglerDetected,
    run_with_restarts,
)
from repro.optim import (
    Adafactor,
    AdamW,
    compress_with_feedback,
    cosine_with_warmup,
    dequantize_int8,
    init_residual,
    quantize_int8,
)


# -- optimizers ----------------------------------------------------------------

def _quadratic_problem():
    target = jnp.asarray([1.0, -2.0, 3.0])
    params = {"w": jnp.zeros((3,))}

    def loss(p):
        return jnp.sum(jnp.square(p["w"] - target))

    return params, loss, target


@pytest.mark.parametrize(
    "opt,atol",
    [
        (AdamW(lr=0.1, weight_decay=0.0), 0.1),
        # Adafactor's RMS update clipping makes it hover within ~lr/2 of the
        # optimum on this toy problem without an lr decay — test the basin.
        (Adafactor(lr=0.5), 0.3),
    ],
)
def test_optimizer_converges(opt, atol):
    params, loss, target = _quadratic_problem()
    state = opt.init(params)
    start = float(loss(params))
    for _ in range(300):
        g = jax.grad(loss)(params)
        params, state = opt.update(g, state, params)
    np.testing.assert_allclose(np.asarray(params["w"]), np.asarray(target), atol=atol)
    assert float(loss(params)) < 0.05 * start


def test_adafactor_memory_is_factored():
    p = {"big": jnp.zeros((64, 128))}
    st_ = Adafactor().init(p)
    r, c = st_.stats["big"]["r"], st_.stats["big"]["c"]
    assert r.shape == (64,) and c.shape == (128,)  # O(n+m), not O(n*m)


def test_cosine_schedule_shape():
    sched = cosine_with_warmup(1e-3, 10, 100)
    assert float(sched(jnp.asarray(0))) == 0.0
    assert float(sched(jnp.asarray(10))) == pytest.approx(1e-3)
    assert float(sched(jnp.asarray(100))) == pytest.approx(1e-4, rel=0.01)


# -- data pipeline ---------------------------------------------------------------

def test_data_deterministic_and_host_invariant():
    cfg = get_smoke_config("olmo-1b")
    shape = SHAPES["train_4k"]
    import dataclasses

    shape = dataclasses.replace(shape, seq_len=16, global_batch=8)
    one_host = SyntheticLM(cfg, shape, DataConfig(seed=7, num_hosts=1, host_id=0))
    full = one_host.batch(3)
    # Two-host layout must produce exactly the same global batch, split.
    h0 = SyntheticLM(cfg, shape, DataConfig(seed=7, num_hosts=2, host_id=0)).batch(3)
    h1 = SyntheticLM(cfg, shape, DataConfig(seed=7, num_hosts=2, host_id=1)).batch(3)
    np.testing.assert_array_equal(
        np.concatenate([h0["tokens"], h1["tokens"]]), full["tokens"]
    )
    # Restart reproducibility.
    again = SyntheticLM(cfg, shape, DataConfig(seed=7)).batch(3)
    np.testing.assert_array_equal(again["tokens"], full["tokens"])


def test_labels_are_shifted_tokens():
    cfg = get_smoke_config("olmo-1b")
    import dataclasses

    shape = dataclasses.replace(SHAPES["train_4k"], seq_len=16, global_batch=2)
    b = SyntheticLM(cfg, shape, DataConfig()).batch(0)
    np.testing.assert_array_equal(b["tokens"][:, 1:], b["labels"][:, :-1])


def test_embeds_source_for_frontend_stubs():
    cfg = get_smoke_config("qwen2-vl-7b")
    import dataclasses

    shape = dataclasses.replace(SHAPES["train_4k"], seq_len=8, global_batch=2)
    b = make_source(cfg, shape, DataConfig()).batch(0)
    assert b["embeds"].shape == (2, 8, cfg.d_model)
    assert b["positions"].shape == (2, 8, 3)


def test_prefetch_iterator():
    cfg = get_smoke_config("olmo-1b")
    import dataclasses

    shape = dataclasses.replace(SHAPES["train_4k"], seq_len=8, global_batch=2)
    src = SyntheticLM(cfg, shape, DataConfig(seed=1))
    it = PrefetchIterator(src, start_step=0, prefetch=2)
    try:
        b0, b1 = next(it), next(it)
        np.testing.assert_array_equal(b0["tokens"], src.batch(0)["tokens"])
        np.testing.assert_array_equal(b1["tokens"], src.batch(1)["tokens"])
    finally:
        it.close()


# -- checkpointing ----------------------------------------------------------------

def _tree():
    return {
        "layers": {"w": jnp.arange(12, dtype=jnp.float32).reshape(3, 4)},
        "step": jnp.asarray(7, jnp.int32),
    }


def test_checkpoint_roundtrip(tmp_path):
    mgr = CheckpointManager(str(tmp_path))
    tree = _tree()
    mgr.save(5, tree)
    assert mgr.latest_step() == 5
    out = mgr.restore(5, jax.tree.map(lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype), tree))
    np.testing.assert_array_equal(np.asarray(out["layers"]["w"]), np.asarray(tree["layers"]["w"]))
    assert int(out["step"]) == 7


def test_checkpoint_atomic_no_partial_on_crash(tmp_path):
    """A .tmp directory must never be visible as a restorable step."""
    mgr = CheckpointManager(str(tmp_path))
    mgr.save(1, _tree())
    os.makedirs(tmp_path / "step_0000000002.tmp")  # simulated crash mid-save
    assert mgr.all_steps() == [1]


def test_checkpoint_retention(tmp_path):
    mgr = CheckpointManager(str(tmp_path), keep=2)
    for s in (1, 2, 3, 4):
        mgr.save(s, _tree())
    assert mgr.all_steps() == [3, 4]


def test_checkpoint_async(tmp_path):
    mgr = CheckpointManager(str(tmp_path))
    mgr.save_async(9, _tree())
    mgr.wait()
    assert mgr.latest_step() == 9


def test_checkpoint_mismatch_detected(tmp_path):
    mgr = CheckpointManager(str(tmp_path))
    mgr.save(1, _tree())
    bad_target = {"other": jax.ShapeDtypeStruct((3, 4), jnp.float32)}
    with pytest.raises(ValueError):
        mgr.restore(1, bad_target)


def test_checkpoint_elastic_reshard(tmp_path):
    """Restore with explicit shardings onto a (1-device) mesh — the elastic
    resume path (same API re-shards onto any mesh shape)."""
    from jax.sharding import NamedSharding, PartitionSpec as P

    mesh = jax.make_mesh((1,), ("data",), axis_types=(jax.sharding.AxisType.Auto,))
    mgr = CheckpointManager(str(tmp_path))
    tree = _tree()
    mgr.save(2, tree)
    target = jax.tree.map(lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype), tree)
    shardings = jax.tree.map(lambda _: NamedSharding(mesh, P()), target)
    out = mgr.restore(2, target, shardings=shardings)
    assert out["layers"]["w"].sharding == NamedSharding(mesh, P())


# -- fault tolerance ----------------------------------------------------------------

def test_watchdog_detects_straggler():
    wd = StepWatchdog(timeout_factor=3.0, warmup_steps=2)
    for _ in range(5):
        wd.durations.append(0.1)
    with pytest.raises(StragglerDetected):
        wd.check(1.0)


def test_watchdog_tolerates_normal_jitter():
    wd = StepWatchdog(timeout_factor=3.0, warmup_steps=2)
    for _ in range(5):
        wd.durations.append(0.1)
    wd.check(0.25)  # 2.5x median: fine


def test_preemption_flag():
    h = PreemptionHandler(install=False)
    assert not h.requested
    h.trigger()
    assert h.requested


def test_run_with_restarts_recovers_from_crash(tmp_path):
    """Simulated node failure mid-training: restart resumes from the latest
    checkpoint and completes."""
    mgr = CheckpointManager(str(tmp_path))
    crashed = {"yet": False}

    def make_state():
        step = mgr.latest_step()
        if step is None:
            return {"x": jnp.zeros(()), "step": 0}
        t = mgr.restore(step, {"x": jax.ShapeDtypeStruct((), jnp.float32)})
        return {"x": t["x"], "step": step}

    def run_steps(state, n):
        x, step = state["x"], state["step"]
        while step < n:
            x = x + 1.0
            step += 1
            mgr.save(step, {"x": x})
            if step == 4 and not crashed["yet"]:
                crashed["yet"] = True
                raise RuntimeError("injected node failure")
        return {"x": x, "step": step}

    state, restarts = run_with_restarts(make_state, run_steps, steps_per_attempt=8)
    assert restarts == 1
    assert state["step"] == 8 and float(state["x"]) == 8.0


# -- gradient compression ---------------------------------------------------------

@settings(max_examples=30, deadline=None)
@given(st.integers(min_value=0, max_value=2**31 - 1))
def test_quantize_roundtrip_bounded_error(seed):
    x = jax.random.normal(jax.random.PRNGKey(seed), (64,)) * 3.0
    q, s = quantize_int8(x)
    err = jnp.abs(dequantize_int8(q, s) - x)
    assert float(err.max()) <= float(s) * 0.5 + 1e-7


def test_error_feedback_preserves_signal():
    """With error feedback, the *accumulated* compressed signal converges to
    the true accumulated gradient (bias-free compression)."""
    g = {"w": jnp.asarray([0.001, -0.02, 0.3])}
    residual = init_residual(g)
    total = jnp.zeros(3)
    for _ in range(100):
        q, s, residual = compress_with_feedback(g, residual)
        total = total + dequantize_int8(q["w"], s["w"])
    np.testing.assert_allclose(np.asarray(total / 100), np.asarray(g["w"]), rtol=0.02)
