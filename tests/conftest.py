"""Session setup for the test suite.

1. Force an 8-device CPU host platform *before* jax initializes its
   backend: the distribution / elastic-rescale tests need a real
   multi-device mesh.  (Individual test modules also set this defensively
   for standalone runs, but the backend is process-global — it must be in
   the environment before the first device query anywhere in the session.)
2. If `hypothesis` is not installed, register the deterministic stub from
   ``_hypothesis_stub.py`` under its name so the property tests still run
   (with plain random sampling instead of real shrinking search).
3. Provide the ``jit_recompiles`` fixture: an XLA-compilation counter the
   serving tests use to pin "compiles once per prefill bucket, never per
   prompt length".  Since PR 10 it is a thin wrapper over the library
   counter ``repro.obs.JitCompileWatcher`` (same log-record mechanism,
   now also wirable into a metrics registry).
"""

import importlib.util
import os
import sys
from pathlib import Path

import pytest

if "xla_force_host_platform_device_count" not in os.environ.get("XLA_FLAGS", ""):
    os.environ["XLA_FLAGS"] = (
        os.environ.get("XLA_FLAGS", "") + " --xla_force_host_platform_device_count=8"
    )

try:
    import hypothesis  # noqa: F401
except ImportError:
    _stub_path = Path(__file__).with_name("_hypothesis_stub.py")
    _spec = importlib.util.spec_from_file_location("hypothesis", _stub_path)
    _stub = importlib.util.module_from_spec(_spec)
    _spec.loader.exec_module(_stub)
    sys.modules["hypothesis"] = _stub
    sys.modules["hypothesis.strategies"] = _stub.strategies


@pytest.fixture
def jit_recompiles():
    # Imported here (not at module top) so the XLA_FLAGS env setup above
    # always runs before anything pulls in jax.
    from repro.obs import watch_jit_compiles

    with watch_jit_compiles() as handler:
        yield handler
