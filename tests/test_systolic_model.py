"""Cycle/utilization model tests (paper §2.2, §3.5, §6.1, §8.2)."""

import pytest

from repro.core.systolic_model import (
    attention_flops,
    baseline_utilization,
    figure11,
    fsa_attention_cycles,
    fsa_tile_cycles,
    fsa_utilization,
    matmul_cycles,
    naive_tile_cycles,
)


def test_matmul_cycles_section22():
    """N x N array, N x M moving matrix: M + 3N - 1 cycles."""
    assert matmul_cycles(1024, 128) == 1024 + 3 * 128 - 1


def test_matmul_cycles_degenerate_shapes():
    """The M + 3N - 1 pipeline formula holds at the degenerate extremes."""
    # A single moving column still pays the full 3N - 1 fill/drain latency.
    assert matmul_cycles(1, 128) == 3 * 128
    assert matmul_cycles(1, 1) == 3  # 1x1 array, one column: 1 + 3 - 1
    # A 1-wide array is a dot-product pipe: M columns + 2 cycles of skew.
    assert matmul_cycles(4096, 1) == 4096 + 2


def test_tile_cycle_formulas():
    for n in (64, 128, 256):
        assert fsa_tile_cycles(n) == 5 * n + 10
        assert fsa_tile_cycles(n, single_direction=True) == 6 * n + 10
        assert naive_tile_cycles(n) == 8 * n - 2


def test_fsa_beats_naive_per_tile():
    assert fsa_tile_cycles(128) < naive_tile_cycles(128)


def test_utilization_asymptote():
    """Util -> 2N/(5N+10) as seq grows (~0.394 at N=128)."""
    assert fsa_utilization(16384) == pytest.approx(2 * 128 / (5 * 128 + 10), rel=0.01)
    assert fsa_utilization(2048) < fsa_utilization(16384)


def test_figure11_reproduces_paper_speedups():
    fig = figure11()
    assert fig["speedup_vs_tpu_v5e"] == pytest.approx(1.77, rel=0.01)
    assert fig["speedup_vs_neuron_v2"] == pytest.approx(4.83, rel=0.01)
    # Paper §6.1: Neuron achieves <25% utilization; FSA ~0.39.
    assert fig["mean_neuron_v2"] < 0.25
    assert 0.35 < fig["mean_fsa"] < 0.45


def test_single_direction_variant_still_beats_baselines():
    """§8.2: the area-optimized variant still outperforms both baselines."""
    util = fsa_utilization(8192, single_direction=True)
    assert util > baseline_utilization("tpu_v5e", 8192)
    assert util > baseline_utilization("neuron_v2", 8192)


def test_attention_flops_formula():
    assert attention_flops(2048, 128) == 4 * 2048 * 2048 * 128


def test_whole_head_cycles():
    # Tr = Tc = 2: 4 inner tiles + 2 rescales.
    assert fsa_attention_cycles(256) == 4 * (5 * 128 + 10) + 2 * (2 * 128 + 20)


def test_whole_head_cycles_single_direction():
    """§8.2 variant: inner tiles cost 6N + 10; the epilogue is unchanged."""
    assert fsa_attention_cycles(256, single_direction=True) == 4 * (
        6 * 128 + 10
    ) + 2 * (2 * 128 + 20)
    # The variant is exactly Tr*Tc*N cycles slower than the standard schedule.
    for seq in (256, 1024):
        tiles = (seq // 128) ** 2
        assert (
            fsa_attention_cycles(seq, single_direction=True)
            - fsa_attention_cycles(seq)
            == tiles * 128
        )
