"""Deterministic micro-fallback for `hypothesis` (used only when the real
package is not installed — see conftest.py).

Implements exactly the surface this test suite uses: ``@given`` over
``st.integers`` / ``st.floats`` / ``st.booleans`` plus ``@settings`` with
``max_examples``.  Examples are drawn from a PRNG seeded by the test's
qualified name, so runs are reproducible; there is no shrinking and no
example database.  Install the real dependency (``pip install -e .[dev]``)
for full property-based testing.
"""

from __future__ import annotations

import random
import types


class _Strategy:
    def __init__(self, draw):
        self._draw = draw

    def example_from(self, rng: random.Random):
        return self._draw(rng)


def integers(min_value: int = 0, max_value: int = 2**31 - 1) -> _Strategy:
    return _Strategy(lambda rng: rng.randint(min_value, max_value))


def booleans() -> _Strategy:
    return _Strategy(lambda rng: rng.random() < 0.5)


def floats(
    min_value: float = 0.0,
    max_value: float = 1.0,
    allow_nan: bool = True,
    allow_infinity: bool = True,
    width: int = 64,
) -> _Strategy:
    del allow_nan, allow_infinity, width
    lo, hi = float(min_value), float(max_value)

    def draw(rng: random.Random) -> float:
        # Bias toward the boundaries, where PWL/exp edge cases live.
        r = rng.random()
        if r < 0.05:
            return lo
        if r < 0.10:
            return hi
        return rng.uniform(lo, hi)

    return _Strategy(draw)


def sampled_from(values) -> _Strategy:
    seq = list(values)
    return _Strategy(lambda rng: seq[rng.randrange(len(seq))])


_MAX_EXAMPLES_ATTR = "_stub_max_examples"
_DEFAULT_MAX_EXAMPLES = 20


def settings(max_examples: int | None = None, deadline=None, **_kw):
    def deco(fn):
        if max_examples is not None:
            setattr(fn, _MAX_EXAMPLES_ATTR, max_examples)
        return fn

    return deco


def given(*strategies: _Strategy, **kw_strategies: _Strategy):
    def deco(fn):
        def wrapper():
            n = getattr(
                wrapper,
                _MAX_EXAMPLES_ATTR,
                getattr(fn, _MAX_EXAMPLES_ATTR, _DEFAULT_MAX_EXAMPLES),
            )
            rng = random.Random(fn.__qualname__)
            for _ in range(n):
                args = [s.example_from(rng) for s in strategies]
                kwargs = {k: s.example_from(rng) for k, s in kw_strategies.items()}
                fn(*args, **kwargs)

        # NOTE: deliberately no functools.wraps — pytest must see a
        # zero-argument signature, not the wrapped function's parameters.
        wrapper.__name__ = fn.__name__
        wrapper.__qualname__ = fn.__qualname__
        wrapper.__doc__ = fn.__doc__
        wrapper.__module__ = fn.__module__
        return wrapper

    return deco


strategies = types.ModuleType("hypothesis.strategies")
strategies.integers = integers
strategies.booleans = booleans
strategies.floats = floats
strategies.sampled_from = sampled_from
