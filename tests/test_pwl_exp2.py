"""PWL exp2 (paper §3.3 / Fig. 12): correctness + paper-claim reproduction."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core.pwl_exp2 import pwl_error_stats, pwl_exp2, segment_table


def test_paper_fig12_8_segments():
    """Paper: 8 segments -> MAE 0.00014, MRE 0.02728 over negative normal fp16."""
    stats = pwl_error_stats(8)
    assert stats["mae"] == pytest.approx(1.4e-4, rel=0.1)
    assert stats["mre"] == pytest.approx(0.02728, rel=0.02)


def test_mae_decreases_mre_stable():
    """Fig. 12 shape: MAE drops with segments, MRE plateaus."""
    s4, s8, s16 = (pwl_error_stats(k) for k in (4, 8, 16))
    assert s4["mae"] > s8["mae"] > s16["mae"]
    assert abs(s8["mre"] - s16["mre"]) < 0.005


def test_intercepts_in_half_open_unit_range():
    """Paper §3.3: all intercepts lie in (0.5, 1] (used to encode k)."""
    for k in (2, 4, 8, 16, 32):
        _, intercept = segment_table(k)
        assert np.all(intercept > 0.5) and np.all(intercept <= 1.0)


def test_exact_at_breakpoints():
    """Chord interpolation is exact at segment breakpoints and at 0."""
    x = jnp.asarray([-0.875, -0.75, -0.5, -0.25, -0.125, 0.0, -1.0, -2.0, -5.0])
    np.testing.assert_allclose(
        np.asarray(pwl_exp2(x)), np.exp2(np.asarray(x)), rtol=1e-6
    )


@settings(max_examples=200, deadline=None)
@given(st.floats(min_value=-100.0, max_value=0.0, allow_nan=False))
def test_relative_error_bound(x):
    """Property: for any x in [-100, 0], PWL rel error < 1% at 8 segments."""
    approx = float(pwl_exp2(jnp.float32(x)))
    exact = float(np.exp2(np.float64(x)))
    if exact > 1e-30:
        assert abs(approx - exact) / exact < 0.01


@settings(max_examples=50, deadline=None)
@given(
    st.integers(min_value=2, max_value=64),
    st.floats(min_value=-30.0, max_value=0.0, allow_nan=False),
)
def test_monotone_in_segments(k, x):
    """More segments never increases the error (chord construction)."""
    e_k = abs(float(pwl_exp2(jnp.float32(x), num_segments=k)) - float(np.exp2(np.float64(x))))
    e_2k = abs(float(pwl_exp2(jnp.float32(x), num_segments=2 * k)) - float(np.exp2(np.float64(x))))
    assert e_2k <= e_k + 1e-9


def test_flush_to_zero():
    assert float(pwl_exp2(jnp.float32(-200.0))) == 0.0


def test_vectorized_shapes_dtypes():
    for dtype in (jnp.float32, jnp.bfloat16, jnp.float16):
        x = -jnp.abs(jax.random.normal(jax.random.PRNGKey(0), (7, 13), jnp.float32)) * 5
        out = pwl_exp2(x.astype(dtype))
        assert out.shape == x.shape and out.dtype == dtype


# -- Pallas kernel properties (interpret mode) -----------------------------
#
# Same claims, checked against the *kernel* (repro.kernels.pwl_exp2) rather
# than the jnp reference: hardware-faithful chord interpolation must stay
# monotone, hit the segment knots exactly, and keep the Fig. 12 relative
# error envelope.

from repro.kernels.pwl_exp2.kernel import pwl_exp2_pallas  # noqa: E402


def _kernel(x, num_segments=8):
    return pwl_exp2_pallas(jnp.asarray(x, jnp.float32), num_segments=num_segments,
                           interpret=True)


@settings(max_examples=25, deadline=None)
@given(
    st.floats(min_value=-30.0, max_value=0.0, allow_nan=False),
    st.floats(min_value=0.0, max_value=30.0, allow_nan=False),
    st.sampled_from([4, 8, 16]),
)
def test_kernel_monotone_nondecreasing(x, delta, k):
    """Property: exp2 is increasing, and each PWL chord has positive slope —
    so the kernel must be monotone for any x <= x + delta."""
    lo, hi = _kernel([x], k), _kernel([min(x + delta, 0.0)], k)
    assert float(lo[0]) <= float(hi[0]) + 1e-7


@settings(max_examples=25, deadline=None)
@given(st.integers(min_value=0, max_value=29), st.sampled_from([4, 8, 16]))
def test_kernel_exact_at_knots(n, k):
    """Property: chord interpolation is exact wherever the fractional part
    lands on a segment breakpoint i/k (and at every integer, i == 0)."""
    for i in range(k + 1):
        x = -(n + i / k)
        got = float(_kernel([x], k)[0])
        want = float(np.exp2(np.float64(x)))
        assert got == pytest.approx(want, rel=1e-6)


@settings(max_examples=100, deadline=None)
@given(st.floats(min_value=-30.0, max_value=0.0, allow_nan=False))
def test_kernel_max_rel_error_within_fig12(x):
    """Property: at 8 segments every input respects the Fig. 12 max
    relative error (MRE 0.02728; small slack for fp32 arithmetic)."""
    approx = float(_kernel([x])[0])
    exact = float(np.exp2(np.float64(x)))
    assert abs(approx - exact) <= 0.0285 * exact + 1e-30
