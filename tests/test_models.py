"""Per-architecture smoke tests (reduced configs): one forward + one train
step on CPU, shape and finiteness assertions; decode consistency checks."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCH_IDS, get_config, get_smoke_config
from repro.models import decode_step, forward, init_cache, init_params, lm_loss
from repro.optim import AdamW


def _smoke_batch(cfg, key, b=2, s=32):
    if cfg.embedding_inputs:
        batch = {
            "embeds": jax.random.normal(key, (b, s, cfg.d_model), jnp.float32),
            "labels": jax.random.randint(key, (b, s), 0, cfg.vocab_size),
        }
        if cfg.mrope_sections is not None:
            batch["positions"] = jnp.broadcast_to(
                jnp.arange(s, dtype=jnp.int32)[None, :, None], (b, s, 3)
            )
        return batch
    return {
        "tokens": jax.random.randint(key, (b, s), 0, cfg.vocab_size),
        "labels": jax.random.randint(key, (b, s), 0, cfg.vocab_size),
    }


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_smoke_forward_shapes_and_finite(arch):
    cfg = get_smoke_config(arch)
    key = jax.random.PRNGKey(0)
    params = init_params(cfg, key)
    batch = _smoke_batch(cfg, key)
    logits = forward(
        params, cfg,
        tokens=batch.get("tokens"), embeds=batch.get("embeds"),
        positions=batch.get("positions"),
    )
    assert logits.shape == (2, 32, cfg.vocab_size)
    assert bool(jnp.isfinite(logits.astype(jnp.float32)).all())


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_smoke_train_step_reduces_loss(arch):
    """A few steps on a fixed batch must reduce the loss (overfit check)."""
    cfg = get_smoke_config(arch)
    key = jax.random.PRNGKey(1)
    params = init_params(cfg, key)
    batch = _smoke_batch(cfg, key)
    opt = AdamW(lr=1e-3, weight_decay=0.0)
    state = opt.init(params)

    @jax.jit
    def step(params, state):
        loss, g = jax.value_and_grad(lambda p: lm_loss(p, cfg, batch))(params)
        params, state = opt.update(g, state, params)
        return params, state, loss

    losses = []
    for _ in range(5):
        params, state, loss = step(params, state)
        losses.append(float(loss))
    assert all(np.isfinite(losses))
    assert losses[-1] < losses[0]


@pytest.mark.parametrize(
    "arch", [a for a in ARCH_IDS if get_config(a).family != "encoder"]
)
def test_decode_runs_and_is_finite(arch):
    cfg = get_smoke_config(arch)
    key = jax.random.PRNGKey(2)
    params = init_params(cfg, key)
    cache = init_cache(cfg, 2, 16)
    tok = jnp.zeros((2, 1), jnp.int32)
    for i in range(3):
        logits, cache = decode_step(params, cfg, tok, cache, jnp.asarray(i, jnp.int32))
        tok = jnp.argmax(logits[:, -1:], axis=-1).astype(jnp.int32)
        assert bool(jnp.isfinite(logits.astype(jnp.float32)).all())


@pytest.mark.parametrize("arch", ["yi-9b", "olmo-1b", "qwen2.5-32b"])
def test_decode_matches_forward(arch):
    """Token-by-token decode logits == full forward logits (same positions).

    Dense transformer KV-cache correctness: run S tokens through decode and
    compare each step's logits against the teacher-forced forward pass.
    """
    cfg = get_smoke_config(arch)
    key = jax.random.PRNGKey(3)
    params = init_params(cfg, key)
    s = 8
    tokens = jax.random.randint(key, (1, s), 0, cfg.vocab_size)
    full = forward(params, cfg, tokens=tokens)  # [1, S, V]

    cache = init_cache(cfg, 1, s)
    outs = []
    for i in range(s):
        logits, cache = decode_step(
            params, cfg, tokens[:, i : i + 1], cache, jnp.asarray(i, jnp.int32)
        )
        outs.append(logits[:, 0])
    dec = jnp.stack(outs, axis=1)
    np.testing.assert_allclose(
        np.asarray(dec, np.float32), np.asarray(full, np.float32), atol=2e-3
    )


def test_zamba2_decode_matches_forward():
    """Hybrid (Mamba2 + shared attention) cache correctness, incl. the
    shared-attention KV slot scatter."""
    cfg = get_smoke_config("zamba2-1.2b")
    key = jax.random.PRNGKey(4)
    params = init_params(cfg, key)
    s = 8
    tokens = jax.random.randint(key, (1, s), 0, cfg.vocab_size)
    full = forward(params, cfg, tokens=tokens)
    cache = init_cache(cfg, 1, s)
    outs = []
    for i in range(s):
        logits, cache = decode_step(
            params, cfg, tokens[:, i : i + 1], cache, jnp.asarray(i, jnp.int32)
        )
        outs.append(logits[:, 0])
    dec = jnp.stack(outs, axis=1)
    np.testing.assert_allclose(
        np.asarray(dec, np.float32), np.asarray(full, np.float32), atol=5e-3
    )


def test_xlstm_decode_matches_forward():
    """Recurrent-state decode == scan forward for the attention-free arch."""
    cfg = get_smoke_config("xlstm-125m")
    key = jax.random.PRNGKey(5)
    params = init_params(cfg, key)
    s = 8
    tokens = jax.random.randint(key, (1, s), 0, cfg.vocab_size)
    full = forward(params, cfg, tokens=tokens)
    cache = init_cache(cfg, 1, s)
    outs = []
    for i in range(s):
        logits, cache = decode_step(
            params, cfg, tokens[:, i : i + 1], cache, jnp.asarray(i, jnp.int32)
        )
        outs.append(logits[:, 0])
    dec = jnp.stack(outs, axis=1)
    np.testing.assert_allclose(
        np.asarray(dec, np.float32), np.asarray(full, np.float32), atol=5e-3
    )


def test_pwl_mode_end_to_end():
    """The paper-faithful numerics mode runs through a whole model."""
    import dataclasses

    cfg = dataclasses.replace(get_smoke_config("yi-9b"), exp2_impl="pwl")
    key = jax.random.PRNGKey(6)
    params = init_params(cfg, key)
    batch = _smoke_batch(cfg, key)
    loss = lm_loss(params, cfg, batch)
    assert bool(jnp.isfinite(loss))


def test_full_configs_match_assignment():
    """The full configs carry the exact assigned hyperparameters."""
    expect = {
        "qwen3-moe-235b-a22b": (94, 4096, 64, 4, 1536, 151936),
        "arctic-480b": (35, 7168, 56, 8, 4864, 32000),
        "hubert-xlarge": (48, 1280, 16, 16, 5120, 504),
        "olmo-1b": (16, 2048, 16, 16, 8192, 50304),
        "nemotron-4-15b": (32, 6144, 48, 8, 24576, 256000),
        "qwen2.5-32b": (64, 5120, 40, 8, 27648, 152064),
        "yi-9b": (48, 4096, 32, 4, 11008, 64000),
        "qwen2-vl-7b": (28, 3584, 28, 4, 18944, 152064),
        "zamba2-1.2b": (38, 2048, 32, 32, 8192, 32000),
        "xlstm-125m": (12, 768, 4, 4, 0, 50304),
    }
    for arch, (L, d, h, kv, ff, v) in expect.items():
        cfg = get_config(arch)
        assert (cfg.num_layers, cfg.d_model, cfg.num_heads, cfg.num_kv_heads,
                cfg.d_ff, cfg.vocab_size) == (L, d, h, kv, ff, v), arch
    assert get_config("qwen3-moe-235b-a22b").moe.num_experts == 128
    assert get_config("qwen3-moe-235b-a22b").moe.top_k == 8
    assert get_config("arctic-480b").moe.top_k == 2
    assert get_config("arctic-480b").moe.dense_residual
    assert get_config("zamba2-1.2b").ssm.state_dim == 64
