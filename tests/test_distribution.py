"""Distribution tests on a CPU debug mesh: sharding rules, shard_map MoE,
sequence parallelism, pipeline parallelism, compressed gradient reduction.

conftest.py sets xla_force_host_platform_device_count=8 for this module
only via an env marker — see conftest.
"""

import os

import pytest

# These tests need >1 CPU device; they are collected only when the test
# process was started with the device-count flag (tests/conftest.py spawns
# nothing — run `pytest tests/test_distribution.py` standalone or rely on
# the session flag below).
if "xla_force_host_platform_device_count" not in os.environ.get("XLA_FLAGS", ""):
    os.environ["XLA_FLAGS"] = (
        os.environ.get("XLA_FLAGS", "") + " --xla_force_host_platform_device_count=8"
    )

import dataclasses  # noqa: E402

import jax  # noqa: E402
import jax.numpy as jnp  # noqa: E402
import numpy as np  # noqa: E402

from repro.configs import SHAPES, get_config, get_smoke_config  # noqa: E402
from repro.configs.registry import ARCH_IDS, runnable_cells, skipped_cells  # noqa: E402
from repro.dist.pipeline import pipelined_apply  # noqa: E402
from repro.dist.sharding import param_pspec  # noqa: E402
from repro.launch.cells import input_specs, lower_cell  # noqa: E402
from repro.launch.mesh import make_debug_mesh  # noqa: E402
from repro.models import forward, init_params, param_shapes  # noqa: E402

pytestmark = pytest.mark.skipif(
    jax.device_count() < 8, reason="needs 8 host-platform devices"
)


def test_cell_registry_counts():
    cells = runnable_cells()
    skips = skipped_cells()
    assert len(cells) + len(skips) == 40  # 10 archs x 4 shapes
    assert len(cells) == 31
    # hubert skips all decode shapes; full-attention archs skip long_500k
    assert ("hubert-xlarge", "decode_32k") in [(a, s) for a, s, _ in skips]
    assert ("yi-9b", "long_500k") in [(a, s) for a, s, _ in skips]
    assert ("zamba2-1.2b", "long_500k") in cells
    assert ("xlstm-125m", "long_500k") in cells


def test_tp_divisibility_of_sharded_dims():
    """Every dim the rules shard by 'model' must divide 16 for all archs."""
    for arch in ARCH_IDS:
        cfg = get_config(arch)
        shapes = param_shapes(cfg)
        flat, _ = jax.tree_util.tree_flatten_with_path(shapes)
        for path, leaf in flat:
            pstr = "/".join(str(getattr(p, "key", p)) for p in path)
            spec = param_pspec(pstr, tuple(leaf.shape), cfg, 16, 16)
            for dim, ax in enumerate(spec):
                if ax is None:
                    continue
                axes = ax if isinstance(ax, tuple) else (ax,)
                div = {"model": 16, "data": 16, "pod": 2}
                total = int(np.prod([div[a] for a in axes]))
                assert leaf.shape[dim] % total == 0, (arch, pstr, dim, spec)


def test_sharded_forward_matches_unsharded():
    """yi-9b smoke forward: TP+DP+SP sharded == single-device result."""
    cfg = get_smoke_config("yi-9b")
    params = init_params(cfg, jax.random.PRNGKey(0))
    toks = jax.random.randint(jax.random.PRNGKey(1), (4, 32), 0, cfg.vocab_size)
    ref = forward(params, cfg, tokens=toks)
    mesh = make_debug_mesh(2, 4)
    with jax.set_mesh(mesh):
        out = jax.jit(lambda p, t: forward(p, cfg, tokens=t))(params, toks)
    np.testing.assert_allclose(
        np.asarray(out, np.float32), np.asarray(ref, np.float32), atol=2e-3
    )


def test_moe_shard_map_matches_local_no_drop():
    cfg = get_smoke_config("qwen3-moe-235b-a22b")
    cfg = dataclasses.replace(
        cfg, moe=dataclasses.replace(cfg.moe, capacity_factor=16.0)
    )
    params = init_params(cfg, jax.random.PRNGKey(0))
    toks = jax.random.randint(jax.random.PRNGKey(1), (4, 16), 0, cfg.vocab_size)
    ref = forward(params, cfg, tokens=toks)
    mesh = make_debug_mesh(2, 4)
    with jax.set_mesh(mesh):
        out = jax.jit(lambda p, t: forward(p, cfg, tokens=t))(params, toks)
    np.testing.assert_allclose(
        np.asarray(out, np.float32), np.asarray(ref, np.float32), atol=2e-3
    )


def test_debug_mesh_lower_and_compile_cells():
    """Miniature dry-run: smoke configs x {train, decode} compile on a
    2x4 debug mesh with the same lowering code path as production."""
    import repro.launch.cells as cells_mod

    mesh = make_debug_mesh(2, 4)
    for arch in ("olmo-1b", "zamba2-1.2b"):
        smoke = get_smoke_config(arch)
        shape = dataclasses.replace(SHAPES["train_4k"], seq_len=32, global_batch=4)
        cfg = dataclasses.replace(smoke)
        cell = lower_cell(
            arch, "train_4k", mesh,
            cfg_override=dataclasses.replace(cfg, remat=True),
        )
        # NOTE lower_cell reads SHAPES[...]: full shapes are too big for 8
        # CPU devices, so just check it LOWERS (no allocation happens).
        assert cell.lowered is not None


def test_pipeline_parallel_matches_sequential():
    """GPipe over a 4-stage pipeline == sequential layer application."""
    mesh = jax.make_mesh(
        (4,), ("pod",), axis_types=(jax.sharding.AxisType.Auto,)
    )
    k = jax.random.PRNGKey(0)
    stages, width = 4, 16
    ws = jax.random.normal(k, (stages, width, width)) * 0.3

    def stage_fn(w, x):
        return jnp.tanh(x @ w)

    x = jax.random.normal(jax.random.fold_in(k, 1), (8, width))
    seq = x
    for i in range(stages):
        seq = stage_fn(ws[i], seq)
    with jax.set_mesh(mesh):
        out = jax.jit(
            lambda ws, x: pipelined_apply(
                stage_fn, ws, x, num_stages=stages, num_microbatches=4
            )
        )(ws, x)
    np.testing.assert_allclose(np.asarray(out), np.asarray(seq), atol=1e-5)


def test_input_specs_cover_all_cells():
    for arch, shape_name in runnable_cells():
        cfg = get_config(arch)
        specs = input_specs(cfg, SHAPES[shape_name])
        assert specs, (arch, shape_name)
        if SHAPES[shape_name].kind == "decode":
            assert "cache" in specs
        else:
            leaves = jax.tree.leaves(specs["batch"])
            assert all(hasattr(l, "shape") for l in leaves)
