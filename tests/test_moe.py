"""MoE dispatch invariants (hypothesis property tests on _moe_block)."""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.configs.base import ModelConfig, MoEConfig
from repro.models.moe import _moe_block, moe_forward, moe_params


def _cfg(e=8, k=2, d=16, ff=32, cf=1.25):
    return ModelConfig(
        name="m", family="moe", num_layers=1, d_model=d, num_heads=2,
        num_kv_heads=2, d_ff=ff, vocab_size=64, dtype="float32", remat=False,
        moe=MoEConfig(num_experts=e, top_k=k, d_ff_expert=ff, capacity_factor=cf),
    )


def _params(cfg, seed=0):
    return moe_params(jax.random.PRNGKey(seed), cfg, jnp.float32)


@settings(max_examples=25, deadline=None)
@given(
    st.integers(min_value=1, max_value=4),   # batch
    st.integers(min_value=2, max_value=16),  # seq
    st.integers(min_value=0, max_value=2**31 - 1),
)
def test_expert_partition_sums_to_full(b, s, seed):
    """Partitioning experts across ranks and summing partials == running
    all experts on one rank (the shard_map psum-combine invariant)."""
    cfg = _cfg()
    p = _params(cfg, seed % 100)
    x = jax.random.normal(jax.random.PRNGKey(seed), (b, s, cfg.d_model))
    full = _moe_block(x, p["router"], p["gate"], p["up"], p["down"], cfg, 0)
    half = cfg.moe.num_experts // 2
    lo = _moe_block(x, p["router"], p["gate"][:half], p["up"][:half],
                    p["down"][:half], cfg, 0)
    hi = _moe_block(x, p["router"], p["gate"][half:], p["up"][half:],
                    p["down"][half:], cfg, half)
    np.testing.assert_allclose(np.asarray(lo + hi), np.asarray(full), atol=1e-5)


def test_no_drop_at_high_capacity_matches_dense_topk():
    """With capacity_factor -> inf, MoE output == explicit dense top-k mix."""
    cfg = _cfg(cf=100.0)
    p = _params(cfg)
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 8, cfg.d_model))
    out = moe_forward(x, p, cfg)

    xf = x.reshape(-1, cfg.d_model)
    logits = xf @ p["router"]
    probs = jax.nn.softmax(logits, axis=-1)
    top_p, top_e = jax.lax.top_k(probs, cfg.moe.top_k)
    top_p = top_p / top_p.sum(-1, keepdims=True)
    y = jnp.zeros_like(xf)
    for t in range(xf.shape[0]):
        for j in range(cfg.moe.top_k):
            e = int(top_e[t, j])
            h = jax.nn.silu(xf[t] @ p["gate"][e]) * (xf[t] @ p["up"][e])
            y = y.at[t].add(top_p[t, j] * (h @ p["down"][e]))
    np.testing.assert_allclose(
        np.asarray(out.reshape(-1, cfg.d_model)), np.asarray(y), atol=1e-4
    )


def test_capacity_drops_are_bounded():
    """Output of a capacity-1 config differs from no-drop but stays finite
    and at most top_k-scaled (dropped tokens pass through as zeros)."""
    x = jax.random.normal(jax.random.PRNGKey(2), (1, 32, 16))
    cfg_tight = _cfg(cf=0.1)
    cfg_loose = _cfg(cf=100.0)
    p = _params(cfg_tight)
    tight = moe_forward(x, p, cfg_tight)
    loose = moe_forward(x, p, cfg_loose)
    assert bool(jnp.isfinite(tight).all())
    # tight drops most pairs: its norm must be well below the no-drop norm
    assert float(jnp.linalg.norm(tight)) < float(jnp.linalg.norm(loose))


@settings(max_examples=10, deadline=None)
@given(st.integers(min_value=0, max_value=2**31 - 1))
def test_token_order_equivariance(seed):
    """Permuting tokens permutes outputs identically (per-group routing is
    order-dependent only through capacity ties; use no-drop capacity)."""
    cfg = _cfg(cf=100.0)
    p = _params(cfg)
    x = jax.random.normal(jax.random.PRNGKey(seed), (1, 8, cfg.d_model))
    perm = jax.random.permutation(jax.random.PRNGKey(seed + 1), 8)
    out = moe_forward(x, p, cfg)[0]
    out_perm = moe_forward(x[:, perm], p, cfg)[0]
    np.testing.assert_allclose(
        np.asarray(out[perm]), np.asarray(out_perm), atol=1e-5
    )