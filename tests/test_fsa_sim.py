"""FSA device simulator + kernel API (paper §4-5) behaviour tests."""

import numpy as np
import pytest

from repro.core import fsa_kernel_api as F
from repro.core.fsa_flash import fsa_flash_attention
from repro.core.fsa_sim import FSADevice
from repro.core.systolic_model import fsa_attention_cycles


def _exact_attention(q, k, v):
    qf, kf, vf = (a.astype(np.float64) for a in (q, k, v))
    s = qf @ kf.T / np.sqrt(q.shape[-1])
    p = np.exp(s - s.max(-1, keepdims=True))
    p /= p.sum(-1, keepdims=True)
    return p @ vf


@pytest.mark.parametrize("seq", [128, 256, 512])
def test_listing2_kernel_accuracy(seq):
    rng = np.random.default_rng(0)
    d = 128
    q, k, v = (rng.standard_normal((seq, d)).astype(np.float16) for _ in range(3))
    res = fsa_flash_attention(q, k, v)
    ref = _exact_attention(q, k, v)
    mae = np.abs(res.output - ref).mean()
    assert mae < 2e-3  # paper Table 2 territory (PWL exp2 + fp16 inputs)


@pytest.mark.parametrize("seq", [128, 256, 1024])
def test_cycle_counts_match_section35(seq):
    """Simulator timeline == the paper's closed-form 5N+10 / 2N+20 cycles."""
    rng = np.random.default_rng(1)
    d = 128
    q, k, v = (rng.standard_normal((seq, d)).astype(np.float16) for _ in range(3))
    res = fsa_flash_attention(q, k, v)
    assert res.cycles == fsa_attention_cycles(seq, d)


def test_table2_distribution_error_envelope():
    """Table 2 protocol: errors under the paper's heavy-tail input dist stay
    inside the paper's reported envelope (MAE <= 3.4e-2 at its worst).

    Our simulator keeps fp32 inter-PE partial sums (the paper's RTL appears
    to quantize more aggressively — see EXPERIMENTS.md), so our absolute
    MAE is *smaller* than the paper's; the envelope bound is what transfers.
    """
    rng = np.random.default_rng(2)
    for seq in (128, 512):
        shape = (seq, 128)

        def draw():
            x = rng.standard_normal(shape) + rng.standard_normal(shape) * 10.0 * (
                rng.random(shape) < 0.001
            )
            return x.astype(np.float16)

        q, k, v = draw(), draw(), draw()
        res = fsa_flash_attention(q, k, v)
        mae = np.abs(res.output - _exact_attention(q, k, v)).mean()
        assert mae < 3.4e-2


@pytest.mark.parametrize("array_n", [32, 64])
def test_cycle_counts_at_nondefault_array_sizes(array_n):
    """The §3.5 closed forms hold for any N, not just the paper's 128."""
    rng = np.random.default_rng(4)
    seq = 4 * array_n  # Tr = Tc = 4
    q, k, v = (
        rng.standard_normal((seq, array_n)).astype(np.float16) for _ in range(3)
    )
    res = fsa_flash_attention(q, k, v, array_n=array_n)
    tiles = (seq // array_n) ** 2
    outer = seq // array_n
    assert res.cycles == tiles * (5 * array_n + 10) + outer * (2 * array_n + 20)
    assert res.cycles == fsa_attention_cycles(seq, array_n, array_n)
    mae = np.abs(res.output - _exact_attention(q, k, v)).mean()
    assert mae < 2e-3


def test_single_direction_schedule_cycles_and_numerics():
    """§8.2 variant on the simulator: 6N + 10 per inner tile, same outputs.

    The schedule only changes *when* instructions issue (no upward-path
    registers, so AttnScore cannot overlap the preceding preload), not what
    they compute — outputs must be bit-identical to the standard schedule.
    """
    rng = np.random.default_rng(5)
    n, seq = 128, 256
    q, k, v = (rng.standard_normal((seq, n)).astype(np.float16) for _ in range(3))
    std = fsa_flash_attention(q, k, v)
    single = fsa_flash_attention(q, k, v, single_direction=True)
    tiles = (seq // n) ** 2
    assert single.cycles == fsa_attention_cycles(seq, n, single_direction=True)
    assert single.cycles == std.cycles + tiles * n
    np.testing.assert_array_equal(std.output, single.output)


def test_scratchpad_capacity_enforced():
    dev = FSADevice(spad_bytes=1024)
    dev.alloc("spad", "a", (16, 16), np.float16)  # 512 B
    with pytest.raises(MemoryError):
        dev.alloc("spad", "b", (32, 32), np.float16)  # +2048 B


def test_accum_capacity_enforced():
    with pytest.raises(MemoryError):
        fsa_flash_attention(
            np.zeros((128, 128), np.float16),
            np.zeros((128, 128), np.float16),
            np.zeros((128, 128), np.float16),
            accum_bytes=1024,
        )


def test_tile_type_safety():
    dev = FSADevice()

    @F.kernel()
    def bad(Q: F.MTile, K: F.MTile, Vt: F.MTile):
        s = F.alloc_spad((128, 128))
        F.store_tile(s, Q)  # store_tile wants ATile -> AssertionError
        return Q

    with pytest.raises(AssertionError):
        bad(*(np.zeros((128, 128), np.float16),) * 3)


def test_program_records_instruction_stream():
    rng = np.random.default_rng(3)
    q, k, v = (rng.standard_normal((256, 128)).astype(np.float16) for _ in range(3))
    res = fsa_flash_attention(q, k, v)
    ops = [i.op for i in res.program.instrs]
    # 2 outer iterations x (load Q + 2 inner x (ls/load/score/load/value)) + epilogue
    assert ops.count("attn_score") == 4
    assert ops.count("attn_value") == 4
    assert ops.count("reciprocal") == 2
    assert ops.count("attn_lse_norm") == 2
    assert ops.count("store_tile") == 2
