"""Tests for the ``repro.quant`` int8 subsystem.

Covers the dot primitive (closeness + straight-through gradients), the
policy threading (config/registry/flag parsing), quantized-vs-fp32 forward
parity across every model family, the int8 KV cache (footprint, exact
engine token-equivalence, fidelity vs the fp32 cache), and the
grad-compress train step under a mesh (see bottom; needs the 8-device
XLA flag like tests/test_distribution.py).
"""

import os

# The mesh tests at the bottom need >1 CPU device (same pattern as
# tests/test_distribution.py — harmless if already set by the session).
if "xla_force_host_platform_device_count" not in os.environ.get("XLA_FLAGS", ""):
    os.environ["XLA_FLAGS"] = (
        os.environ.get("XLA_FLAGS", "") + " --xla_force_host_platform_device_count=8"
    )

import dataclasses  # noqa: E402

import jax  # noqa: E402
import jax.numpy as jnp  # noqa: E402
import numpy as np  # noqa: E402
import pytest  # noqa: E402

from repro.configs.registry import get_smoke_config  # noqa: E402
from repro.models import forward, init_params  # noqa: E402
from repro.models.attention import QuantKVCache  # noqa: E402
from repro.models.model import decode_step, init_cache  # noqa: E402
from repro.quant import (  # noqa: E402
    Quant,
    QuantConfig,
    dequantize_kv,
    int8_dot,
    parse_quant,
    quantize_kv,
    quantize_rows,
)
from repro.serve import Request, ServeEngine, sequential_greedy_decode  # noqa: E402

from test_serve_engine import MAX_LEN, TINY  # noqa: E402


@pytest.fixture(scope="module", autouse=True)
def _release_compiled_state():
    """Drop this module's compiled executables on teardown.

    These tests compile an unusually large number of distinct programs
    (five-architecture parity, two cache layouts through the engine, jitted
    teacher-forced decode loops); releasing them keeps the process's native
    compiler state small for the modules that run after in a full-suite
    invocation.
    """
    yield
    jax.clear_caches()


# -- primitive ------------------------------------------------------------------


def test_int8_dot_close_to_fp():
    key = jax.random.PRNGKey(0)
    x = jax.random.normal(key, (8, 64), jnp.float32)
    w = jax.random.normal(jax.random.PRNGKey(1), (64, 32), jnp.float32) * 0.1
    exact = x @ w
    approx = int8_dot(x, w)
    rel = jnp.linalg.norm(approx - exact) / jnp.linalg.norm(exact)
    assert rel < 0.02, float(rel)


def test_int8_dot_batched_rank3():
    x = jax.random.normal(jax.random.PRNGKey(0), (2, 5, 16), jnp.float32)
    w = jax.random.normal(jax.random.PRNGKey(1), (16, 8), jnp.float32)
    out = int8_dot(x, w)
    assert out.shape == (2, 5, 8)
    # Per-row activation scales: each token row quantizes independently, so
    # the same row produces bit-identical output at any batch/seq position.
    single = int8_dot(x[1:2, 2:3], w)
    np.testing.assert_array_equal(np.asarray(out[1:2, 2:3]), np.asarray(single))


def test_int8_dot_straight_through_grads():
    x = jax.random.normal(jax.random.PRNGKey(0), (4, 16), jnp.float32)
    w = jax.random.normal(jax.random.PRNGKey(1), (16, 8), jnp.float32)

    gx, gw = jax.grad(lambda x, w: jnp.sum(int8_dot(x, w)), argnums=(0, 1))(x, w)
    # Straight-through: gradients are the fp matmul's, against fp operands.
    ones = jnp.ones((4, 8), jnp.float32)
    np.testing.assert_allclose(np.asarray(gx), np.asarray(ones @ w.T), rtol=1e-5)
    np.testing.assert_allclose(np.asarray(gw), np.asarray(x.T @ ones), rtol=1e-5)


def test_quantize_rows_roundtrip():
    x = jax.random.normal(jax.random.PRNGKey(0), (6, 32), jnp.float32)
    q, s = quantize_rows(x)
    assert q.dtype == jnp.int8 and s.shape == (6, 1)
    err = jnp.max(jnp.abs(q.astype(jnp.float32) * s - x))
    assert err <= jnp.max(jnp.abs(x)) / 127.0 + 1e-6


def test_quantize_kv_per_vector():
    x = jax.random.normal(jax.random.PRNGKey(0), (2, 3, 4, 16), jnp.float32)
    q, scale = quantize_kv(x)
    assert q.shape == x.shape and q.dtype == jnp.int8
    assert scale.shape == (2, 3, 4)
    rec = dequantize_kv(q, scale)
    assert float(jnp.max(jnp.abs(rec - x))) < float(jnp.max(jnp.abs(x))) / 100


# -- policy / config threading ---------------------------------------------------


def test_parse_quant_flags():
    assert parse_quant("none") is None
    full = parse_quant("int8")
    assert full.kv_cache and full.granularity == "per_channel"
    assert parse_quant("int8-per-tensor").granularity == "per_tensor"
    kv_only = parse_quant("int8-kv-only")
    assert kv_only.kv_cache and kv_only.layer_classes == ()
    no_kv = parse_quant("int8-no-kv")
    assert not no_kv.kv_cache and no_kv.layer_classes
    with pytest.raises(ValueError):
        parse_quant("fp4")


def test_registry_threads_quant():
    cfg = get_smoke_config("olmo-1b", "int8")
    assert cfg.quant == QuantConfig()
    assert get_smoke_config("olmo-1b").quant is None
    assert get_smoke_config("olmo-1b", "none").quant is None


def test_policy_inactive_class_falls_back():
    q = Quant(QuantConfig(layer_classes=("mlp",)))
    x = jax.random.normal(jax.random.PRNGKey(0), (4, 8), jnp.float32)
    w = jax.random.normal(jax.random.PRNGKey(1), (8, 8), jnp.float32)
    np.testing.assert_array_equal(
        np.asarray(q.dot(x, w, "attention")), np.asarray(x @ w)
    )
    assert not np.array_equal(np.asarray(q.dot(x, w, "mlp")), np.asarray(x @ w))


# -- forward parity across the model zoo ----------------------------------------

PARITY_ARCHS = [
    "olmo-1b",            # dense
    "qwen3-moe-235b-a22b",  # moe
    "qwen2-vl-7b",        # vlm
    "zamba2-1.2b",        # hybrid (mamba2 + shared attention)
    "xlstm-125m",         # ssm (mLSTM/sLSTM)
]


@pytest.mark.parametrize("arch", PARITY_ARCHS)
def test_forward_parity_quant_vs_fp32(arch):
    cfg = get_smoke_config(arch)
    params = init_params(cfg, jax.random.PRNGKey(0))
    if cfg.embedding_inputs:
        kw = {"embeds": jax.random.normal(
            jax.random.PRNGKey(1), (2, 16, cfg.d_model), jnp.float32)}
    else:
        kw = {"tokens": jax.random.randint(
            jax.random.PRNGKey(1), (2, 16), 0, cfg.vocab_size)}
    ref = forward(params, cfg, **kw)
    out = forward(params, get_smoke_config(arch, "int8"), **kw)
    d = np.asarray(out - ref, np.float64)
    r = np.asarray(ref, np.float64)
    rel = np.linalg.norm(d) / np.linalg.norm(r)
    # Measured on these smoke configs: 0.015-0.066 across families.
    assert rel < 0.15, f"{arch}: rel logit error {rel:.4f}"
    assert np.isfinite(d).all()


# -- int8 KV cache ---------------------------------------------------------------

KV_CFG = dataclasses.replace(TINY, quant=parse_quant("int8-kv-only"))
Q_CFG = dataclasses.replace(TINY, quant=QuantConfig())


def test_quant_cache_structure_and_footprint():
    fp = init_cache(TINY, 1, MAX_LEN)
    q = init_cache(Q_CFG, 1, MAX_LEN)
    assert isinstance(q, QuantKVCache)
    assert q.k.dtype == jnp.int8 and q.k_scale.dtype == jnp.float32
    # [L, B, S, Hkv, d] payloads, [L, B, S, Hkv] scales, [L, B] lengths.
    hd = TINY.resolved_head_dim
    assert q.k.shape == (TINY.num_layers, 1, MAX_LEN, TINY.num_kv_heads, hd)
    assert q.k_scale.shape == (TINY.num_layers, 1, MAX_LEN, TINY.num_kv_heads)

    nbytes = lambda t: sum(x.size * x.dtype.itemsize for x in jax.tree.leaves(t))
    ratio = nbytes(fp) / nbytes(q)
    assert ratio >= 3.0, ratio  # (d+4)/4d = 3.2x at head_dim 16


def test_quant_decode_step_runs():
    params = init_params(TINY, jax.random.PRNGKey(0))
    cache = init_cache(Q_CFG, 2, MAX_LEN)
    toks = jnp.array([[3], [5]], jnp.int32)
    logits, new_cache = decode_step(params, Q_CFG, toks, cache, jnp.zeros(2, jnp.int32))
    assert logits.shape == (2, 1, TINY.vocab_size)
    assert isinstance(new_cache, QuantKVCache)
    assert int(jnp.sum(jnp.abs(new_cache.k.astype(jnp.int32)))) > 0


def test_engine_token_equivalence_under_quant():
    """Continuous batching must not change tokens — also under int8.

    Per-row activation scales and per-token KV scales make chunked prefill
    and decode bit-identical per token, so the equivalence is *exact*.
    """
    params = init_params(TINY, jax.random.PRNGKey(0))
    rng = np.random.default_rng(0)
    prompts = [
        rng.integers(1, TINY.vocab_size, size=n).astype(np.int32)
        for n in (3, 7, 12, 5)
    ]
    refs = [
        sequential_greedy_decode(Q_CFG, params, p, 10, max_len=MAX_LEN)
        for p in prompts
    ]
    eng = ServeEngine(Q_CFG, params, batch_size=2, max_len=MAX_LEN)
    for i, p in enumerate(prompts):
        eng.submit(Request(rid=i, prompt=p, max_new_tokens=10))
    done = {r.rid: r.output for r in eng.run()}
    for i, ref in enumerate(refs):
        assert done[i] == ref, (i, done[i], ref)


def test_chunked_prefill_matches_unchunked_under_quant():
    params = init_params(TINY, jax.random.PRNGKey(0))
    prompt = np.arange(1, 20, dtype=np.int32) % TINY.vocab_size

    def decode(chunk):
        eng = ServeEngine(
            Q_CFG, params, batch_size=1, max_len=MAX_LEN, prefill_chunk=chunk
        )
        eng.submit(Request(rid=0, prompt=prompt, max_new_tokens=8))
        return eng.run()[0].output

    assert decode(None) == decode(8)


def test_int8_kv_cache_fidelity_vs_fp32_cache():
    """Decoding against the int8 KV cache picks the same greedy token as
    the fp32 cache >= 95% of the time.

    Teacher-forced: the *same* token stream feeds both caches step by
    step, isolating the cache-quantization effect (a free-running
    comparison compounds trajectory divergence after any disagreement —
    measured 0.98-1.0 here across seeds vs 0.82-0.96 free-running)."""
    import functools

    params = init_params(TINY, jax.random.PRNGKey(0))
    toks = jax.random.randint(jax.random.PRNGKey(100), (2, 32), 1, TINY.vocab_size)
    step_fp = jax.jit(functools.partial(decode_step, cfg=TINY))
    step_q = jax.jit(functools.partial(decode_step, cfg=KV_CFG))
    cache_fp = init_cache(TINY, 2, MAX_LEN)
    cache_q = init_cache(KV_CFG, 2, MAX_LEN)
    agree = total = 0
    for t in range(32):
        tok = toks[:, t:t + 1]
        pos = jnp.full((2,), t, jnp.int32)
        lf, cache_fp = step_fp(params, tokens=tok, cache=cache_fp, position=pos)
        lq, cache_q = step_q(params, tokens=tok, cache=cache_q, position=pos)
        agree += int((jnp.argmax(lf, -1) == jnp.argmax(lq, -1)).sum())
        total += 2
    assert agree / total >= 0.95, agree / total


# -- grad compression under a mesh ----------------------------------------------

mesh_only = pytest.mark.skipif(
    jax.device_count() < 8, reason="needs 8 host-platform devices"
)


@mesh_only
def test_trainer_compress_grads_under_mesh(tmp_path):
    from repro.configs.base import ShapeConfig
    from repro.launch.mesh import make_debug_mesh
    from repro.train.trainer import Trainer, TrainerConfig

    cfg = get_smoke_config("olmo-1b", "int8")
    tcfg = TrainerConfig(
        total_steps=3, ckpt_every=100, log_every=10,
        ckpt_dir=str(tmp_path / "ckpt"), compress_grads=True,
    )
    mesh = make_debug_mesh(4, 2)
    tr = Trainer(cfg, ShapeConfig("t", 32, 8, "train"), tcfg, mesh=mesh)
    state = tr.run()
    assert state["step"] == 3
    assert "residual" in state
    assert np.isfinite(state["losses"]).all()
    # Error feedback is live: residuals are non-zero after a step.
    res_norm = sum(
        float(jnp.sum(jnp.abs(r))) for r in jax.tree.leaves(state["residual"])
    )
    assert res_norm > 0.0


@mesh_only
def test_quant_cache_shardings_cover_quant_leaves():
    from repro.dist.sharding import cache_shardings
    from repro.launch.mesh import make_debug_mesh

    mesh = make_debug_mesh(4, 2)
    cache = init_cache(Q_CFG, 4, MAX_LEN)
    sh = cache_shardings(cache, Q_CFG, mesh)
    assert isinstance(sh, QuantKVCache)
    # int8 payloads shard batch over data and heads over model; the scale
    # tree co-shards; lengths shard batch only.
    assert sh.k.spec == jax.sharding.PartitionSpec(None, "data", None, "model", None)
    assert sh.k_scale.spec == jax.sharding.PartitionSpec(None, "data", None, "model")
    assert sh.lengths.spec == jax.sharding.PartitionSpec(None, "data")
    placed = jax.device_put(cache, sh)
    assert isinstance(placed, QuantKVCache)
