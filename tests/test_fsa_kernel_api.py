"""FSA kernel-API coverage (paper §5): tile views, SRAM capacity
enforcement, and eager-vs-replayed program equivalence."""

import numpy as np
import pytest

import repro.core.fsa_kernel_api as F
from repro.core.fsa_sim import FSADevice


# -- split() views ------------------------------------------------------------

def test_split_nested_views_read_and_write_through():
    """split() of a split() stays a live view of the root tile: reads see
    the parent's data and store_tile into the nested view lands in the
    parent's backing array (Listing 2 writes O tiles through views)."""
    base = np.arange(32, dtype=np.float32).reshape(4, 8)

    @F.kernel()
    def k():
        m = F.alloc_mem((4, 8), np.float32, data=base)
        cols = m.split(4, dim=-1)          # two [4, 4] views
        quads = cols[1].split(2, dim=0)    # two [2, 4] views of a view
        np.testing.assert_array_equal(quads[1].to_numpy(), base[2:4, 4:8])

        a = F.alloc_accum((2, 4))
        a._write(F._ctx().device.accum, np.full((2, 4), 7.0, np.float32))
        F.store_tile(a, quads[1])          # write-through the nested view
        # Sibling views and untouched rows are unchanged.
        np.testing.assert_array_equal(cols[0].to_numpy(), base[:, :4])
        np.testing.assert_array_equal(quads[0].to_numpy(), base[0:2, 4:8])
        return m

    out = k().output
    expect = base.copy()
    expect[2:4, 4:8] = 7.0
    np.testing.assert_array_equal(out, expect)


def test_split_requires_even_division():
    @F.kernel()
    def k():
        m = F.alloc_mem((4, 6), np.float32, data=np.zeros((4, 6)))
        with pytest.raises(AssertionError):
            m.split(4, dim=-1)  # 6 % 4 != 0
        return m

    k()


# -- SRAM capacity enforcement (Table 1) --------------------------------------

def test_scratchpad_capacity_enforced():
    """192 KiB scratchpad: an allocation at the limit succeeds, one element
    more raises MemoryError (fp16 = 2 bytes/elem)."""
    at_limit = 192 * 1024 // 2

    @F.kernel()
    def fits():
        F.alloc_spad((at_limit,), np.float16)
        return None

    fits()  # exactly at capacity: fine

    @F.kernel()
    def overflows():
        F.alloc_spad((at_limit,), np.float16)
        F.alloc_spad((1,), np.float16)  # cumulative: one tile over
        return None

    with pytest.raises(MemoryError):
        overflows()


def test_accum_capacity_enforced():
    """64 KiB accumulation SRAM, fp32 = 4 bytes/elem."""
    at_limit = 64 * 1024 // 4

    @F.kernel()
    def overflows():
        F.alloc_accum((at_limit + 1,), np.float32)
        return None

    with pytest.raises(MemoryError):
        overflows()


def test_main_memory_is_unbounded():
    @F.kernel()
    def big():
        F.alloc_mem((1024, 1024), np.float16)  # 2 MiB >> either SRAM
        return None

    big()


# -- eager API vs FSADevice.run on the recorded program -----------------------

def _single_tile_attention(n=32):
    """One whole-tile FlashAttention iteration (no views, so the recorded
    program replays on a bare device)."""
    rng = np.random.default_rng(0)
    Q = rng.standard_normal((n, n)).astype(np.float16)
    K = rng.standard_normal((n, n)).astype(np.float16)
    Vt = np.ascontiguousarray(rng.standard_normal((n, n)).astype(np.float16).T)
    scale = 1.0 / np.sqrt(n)

    @F.kernel(array_n=n)
    def attention(Qm, Km, Vtm):
        out = F.alloc_mem((n, n), np.float32, name="out")
        q_s = F.alloc_spad((n, n))
        k_s = F.alloc_spad((n, n))
        v_s = F.alloc_spad((n, n))
        lse = F.alloc_accum((1, n))
        o = F.alloc_accum((n, n))
        F.load_tile(Qm, q_s)
        F.load_stationary(q_s, transpose=True)
        F.load_tile(Km, k_s)
        F.attn_score(k_s, lse, scale=scale)
        F.load_tile(Vtm, v_s)
        F.attn_value(v_s, o)
        F.reciprocal(lse)
        F.attn_lse_norm(o)
        F.store_tile(o, out)
        return out

    return attention(Q, K, Vt), n


def test_eager_cycles_match_device_run_replay():
    """The @kernel eager path and FSADevice.run must account identical
    cycles for the same instruction stream (§3.5: 5N+10 inner + 2N+20
    epilogue), and produce identical numerics."""
    res, n = _single_tile_attention()
    # One inner iteration + epilogue.
    assert res.cycles == (5 * n + 10) + (2 * n + 20)

    replay = FSADevice(array_n=n)
    # alloc is not an instruction: rehydrate memory images — inputs as the
    # eager device left them, accumulators back to their alloc-time zeros.
    replay.main = {k: v.copy() for k, v in res.device.main.items()}
    replay.accum = {k: np.zeros_like(v) for k, v in res.device.accum.items()}
    replay.run(res.program)

    assert replay.cycles == res.cycles
    np.testing.assert_array_equal(replay.main["out"], res.output)


def test_program_records_full_instruction_stream():
    res, _ = _single_tile_attention()
    ops = [i.op for i in res.program.instrs]
    assert ops == [
        "load_tile", "load_stationary", "load_tile", "attn_score",
        "load_tile", "attn_value", "reciprocal", "attn_lse_norm",
        "store_tile",
    ]
