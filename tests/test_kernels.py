"""Pallas kernel validation (interpret mode): shape/dtype sweeps vs ref.py."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.pwl_exp2 import pwl_exp2 as pwl_exp2_jnp
from repro.kernels.flash_attention.kernel import flash_attention_fwd
from repro.kernels.flash_attention.ops import flash_attention
from repro.kernels.flash_attention.ref import attention_reference
from repro.kernels.pwl_exp2.kernel import pwl_exp2_pallas


def _rand(shape, key, dtype=jnp.float32):
    return jax.random.normal(jax.random.PRNGKey(key), shape, jnp.float32).astype(dtype)


SHAPE_SWEEP = [
    # (B, Sq, Sk, H, Hkv, d, causal)
    (1, 128, 128, 1, 1, 64, False),
    (2, 256, 256, 4, 2, 64, True),
    (1, 256, 512, 4, 1, 128, True),
    (1, 100, 200, 4, 4, 32, True),   # ragged
    (2, 64, 64, 8, 2, 16, False),
]


@pytest.mark.parametrize("case", SHAPE_SWEEP)
def test_flash_fwd_matches_ref(case):
    b, sq, sk, h, hkv, d, causal = case
    q = _rand((b, sq, h, d), 0)
    k = _rand((b, sk, hkv, d), 1)
    v = _rand((b, sk, hkv, d), 2)
    qo = sk - sq if causal else 0
    ref = attention_reference(q, k, v, causal=causal, q_offset=qo)
    out = flash_attention_fwd(
        q, k, v, causal=causal, q_offset=qo, block_q=64, block_k=64, interpret=True
    )
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=3e-5)


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_flash_fwd_dtypes(dtype):
    q = _rand((1, 128, 2, 64), 0, dtype)
    k = _rand((1, 128, 2, 64), 1, dtype)
    v = _rand((1, 128, 2, 64), 2, dtype)
    ref = attention_reference(q, k, v, causal=True)
    out = flash_attention_fwd(q, k, v, causal=True, interpret=True)
    assert out.dtype == dtype
    tol = 3e-5 if dtype == jnp.float32 else 2e-2
    np.testing.assert_allclose(
        np.asarray(out, np.float32), np.asarray(ref, np.float32), atol=tol
    )


def test_flash_pwl_matches_table2_envelope():
    """Paper Table 2 distribution: N(0,1) + N(0,100)*Bernoulli(0.001)."""
    rng = np.random.default_rng(0)
    shape = (1, 512, 2, 128)

    def draw(s):
        x = rng.standard_normal(s) + rng.standard_normal(s) * 10.0 * (
            rng.random(s) < 0.001
        )
        return jnp.asarray(x, jnp.float32)

    q, k, v = draw(shape), draw(shape), draw(shape)
    ref = attention_reference(q, k, v)
    out = flash_attention_fwd(q, k, v, exp2_impl="pwl", interpret=True)
    mae = float(jnp.abs(out - ref).mean())
    assert mae < 2e-2  # Table 2 reports MAE 8e-3..3.4e-2 over 2k..16k


def test_flash_custom_vjp_matches_autodiff_of_ref():
    q = _rand((1, 128, 2, 32), 0)
    k = _rand((1, 128, 1, 32), 1)
    v = _rand((1, 128, 1, 32), 2)

    def f_kernel(q, k, v):
        return (flash_attention(q, k, v, True) * 0.1).sum()

    def f_ref(q, k, v):
        return (attention_reference(q, k, v, causal=True) * 0.1).sum()

    g1 = jax.grad(f_kernel, argnums=(0, 1, 2))(q, k, v)
    g2 = jax.grad(f_ref, argnums=(0, 1, 2))(q, k, v)
    for a, b in zip(g1, g2):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=3e-5)


@pytest.mark.parametrize("shape", [(8,), (1000, 37), (3, 5, 7), (128, 128)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_pwl_exp2_kernel_sweep(shape, dtype):
    x = -jnp.abs(_rand(shape, 0)) * 8.0
    x = x.astype(dtype)
    out = pwl_exp2_pallas(x, interpret=True)
    ref = pwl_exp2_jnp(x)
    assert out.shape == ref.shape and out.dtype == ref.dtype
    np.testing.assert_allclose(
        np.asarray(out, np.float32), np.asarray(ref, np.float32), atol=2e-3
    )


def test_pwl_exp2_kernel_segment_counts():
    x = -jnp.abs(_rand((256,), 1)) * 4.0
    for k in (4, 8, 16):
        out = pwl_exp2_pallas(x, num_segments=k, interpret=True)
        ref = pwl_exp2_jnp(x, num_segments=k)
        np.testing.assert_allclose(np.asarray(out), np.asarray(ref), rtol=1e-6)


# -- Cross-check against jax.nn.dot_product_attention ----------------------
#
# ref.py shares code style (and potential blind spots) with the kernels; the
# XLA attention is an independent oracle.  Sequence lengths are deliberately
# not multiples of the 64-token blocks so the padded-tail masking is load-
# bearing in every case.


@pytest.mark.parametrize("causal", [False, True])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_flash_fwd_matches_jax_nn(causal, dtype):
    b, s, h, hkv, d = 2, 100, 4, 2, 32  # GQA, ragged vs block_q/block_k=64
    q = _rand((b, s, h, d), 0, dtype)
    k = _rand((b, s, hkv, d), 1, dtype)
    v = _rand((b, s, hkv, d), 2, dtype)
    out = flash_attention_fwd(
        q, k, v, causal=causal, block_q=64, block_k=64, interpret=True
    )
    ref = jax.nn.dot_product_attention(q, k, v, is_causal=causal)
    tol = 3e-5 if dtype == jnp.float32 else 2e-2
    np.testing.assert_allclose(
        np.asarray(out, np.float32), np.asarray(ref, np.float32), atol=tol
    )


@pytest.mark.parametrize("causal", [False, True])
def test_flash_bwd_matches_jax_nn_autodiff(causal):
    b, s, h, hkv, d = 1, 100, 2, 1, 32
    q = _rand((b, s, h, d), 0)
    k = _rand((b, s, hkv, d), 1)
    v = _rand((b, s, hkv, d), 2)
    do = _rand((b, s, h, d), 3)

    def f_kernel(q, k, v):
        o = flash_attention(q, k, v, causal, None, 0, 64, 64, "exact", 8,
                            "pallas", True)
        return (o * do).sum()

    def f_xla(q, k, v):
        return (jax.nn.dot_product_attention(q, k, v, is_causal=causal) * do).sum()

    g1 = jax.grad(f_kernel, argnums=(0, 1, 2))(q, k, v)
    g2 = jax.grad(f_xla, argnums=(0, 1, 2))(q, k, v)
    for a, b_ in zip(g1, g2):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b_), atol=5e-5)


def test_flash_fwd_bf16_ragged_gqa_vs_ref():
    """bf16 + ragged Sq != Sk + causal offset in one case (the decode-cache
    prefill shape class the serving engine emits)."""
    q = _rand((1, 100, 4, 32), 0, jnp.bfloat16)
    k = _rand((1, 200, 2, 32), 1, jnp.bfloat16)
    v = _rand((1, 200, 2, 32), 2, jnp.bfloat16)
    out = flash_attention_fwd(
        q, k, v, causal=True, q_offset=100, block_q=64, block_k=64, interpret=True
    )
    ref = attention_reference(q, k, v, causal=True, q_offset=100)
    assert out.dtype == jnp.bfloat16
    np.testing.assert_allclose(
        np.asarray(out, np.float32), np.asarray(ref, np.float32), atol=2e-2
    )


# -- Pallas backward kernels (FlashAttention-2 dq / dkv) -------------------

from repro.kernels.flash_attention.kernel_bwd import flash_attention_bwd  # noqa: E402


@pytest.mark.parametrize("case", [
    (1, 128, 128, 2, 1, 32, True),
    (2, 256, 192, 4, 2, 64, False),
    (1, 100, 200, 4, 1, 32, True),   # ragged + GQA + causal offset
])
def test_pallas_bwd_matches_autodiff(case):
    b, sq, sk, h, hkv, d, causal = case
    ks = jax.random.split(jax.random.PRNGKey(7), 4)
    q = _rand((b, sq, h, d), 0)
    k = _rand((b, sk, hkv, d), 1)
    v = _rand((b, sk, hkv, d), 2)
    do = _rand((b, sq, h, d), 3)
    qo = sk - sq if causal else 0
    out, lse = flash_attention_fwd(
        q, k, v, causal=causal, q_offset=qo, block_q=64, block_k=64,
        interpret=True, return_lse=True,
    )
    dq, dk, dv = flash_attention_bwd(
        q, k, v, out, lse, do, causal=causal, q_offset=qo,
        block_q=64, block_k=64, interpret=True,
    )
    f = lambda q, k, v: (  # noqa: E731
        attention_reference(q, k, v, causal=causal, q_offset=qo) * do
    ).sum()
    rq, rk, rv = jax.grad(f, argnums=(0, 1, 2))(q, k, v)
    np.testing.assert_allclose(np.asarray(dq), np.asarray(rq), atol=3e-5)
    np.testing.assert_allclose(np.asarray(dk), np.asarray(rk), atol=3e-5)
    np.testing.assert_allclose(np.asarray(dv), np.asarray(rv), atol=3e-5)


def test_pallas_custom_vjp_end_to_end():
    """flash_attention(impl='pallas') trains: full kernel fwd+bwd path."""
    q = _rand((1, 128, 2, 32), 0)
    k = _rand((1, 128, 1, 32), 1)
    v = _rand((1, 128, 1, 32), 2)

    def loss(q, k, v):
        o = flash_attention(q, k, v, True, None, 0, 64, 64, "exact", 8,
                            "pallas", True)
        return (o * o).sum()

    g = jax.grad(loss, argnums=(0, 1, 2))(q, k, v)

    def loss_ref(q, k, v):
        o = attention_reference(q, k, v, causal=True)
        return (o * o).sum()

    gr = jax.grad(loss_ref, argnums=(0, 1, 2))(q, k, v)
    for a, b in zip(g, gr):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=5e-5)
