"""Tests for the ``repro.obs`` telemetry layer (ISSUE 10).

Covers: histogram/percentile math vs numpy, Prometheus/JSON exposition
golden output, Chrome-trace schema validity, the disabled-mode no-op
overhead guard, MFU cross-checks against ``core.systolic_model`` at the
paper point, engine TTFT/TPOT plausibility, the library compile counter
vs the ``jit_recompiles`` fixture, fault-layer counters, trainer metrics
+ the JSONL stream round-trip through ``launch/scrape_log``.
"""

import os

if "xla_force_host_platform_device_count" not in os.environ.get("XLA_FLAGS", ""):
    os.environ["XLA_FLAGS"] = (
        os.environ.get("XLA_FLAGS", "") + " --xla_force_host_platform_device_count=8"
    )

import json  # noqa: E402
import time  # noqa: E402

import jax  # noqa: E402
import numpy as np  # noqa: E402
import pytest  # noqa: E402

from repro.configs.base import ModelConfig, ShapeConfig  # noqa: E402
from repro.core import systolic_model  # noqa: E402
from repro.dist.fault import PreemptionHandler, StepWatchdog  # noqa: E402
from repro.launch.scrape_log import scrape, scrape_dryrun  # noqa: E402
from repro.models import init_params  # noqa: E402
from repro.obs import (  # noqa: E402
    MFUMeter,
    PAPER_ARRAY,
    Registry,
    Tracer,
    decode_flops,
    paper_ideal_flops_per_s,
    prefill_flops,
    set_enabled,
    train_step_flops,
    watch_jit_compiles,
)
from repro.serve.engine import Request, ServeEngine  # noqa: E402
from repro.train.trainer import Trainer, TrainerConfig  # noqa: E402

TINY = ModelConfig(
    name="tiny-obs",
    family="dense",
    num_layers=2,
    d_model=64,
    num_heads=4,
    num_kv_heads=2,
    head_dim=16,
    d_ff=128,
    vocab_size=128,
    mlp_type="swiglu",
    dtype="float32",
    remat=False,
)


@pytest.fixture(autouse=True)
def _metrics_enabled():
    """Every test starts (and leaves the process) with metrics on."""
    set_enabled(True)
    yield
    set_enabled(True)


# ---------------------------------------------------------------------------
# metrics registry
# ---------------------------------------------------------------------------


def test_counter_gauge_basics_and_labels():
    reg = Registry()
    c = reg.counter("reqs_total", "requests", ("phase",))
    c.labels(phase="prefill").inc()
    c.labels(phase="prefill").inc(2)
    c.labels(phase="decode").inc()
    assert c.labels(phase="prefill").value == 3
    assert c.labels(phase="decode").value == 1
    with pytest.raises(ValueError):
        c.labels(phase="x").inc(-1)  # counters only go up

    g = reg.gauge("occupancy", "live fraction")
    g.set(0.75)
    g.inc(0.25)
    assert g.value == 1.0
    # Re-registering the same name returns the same family; kind clashes
    # are errors.
    assert reg.counter("reqs_total") is c
    with pytest.raises(ValueError):
        reg.gauge("reqs_total")


def test_histogram_percentiles_match_numpy():
    reg = Registry()
    h = reg.histogram("lat", "latency")
    rng = np.random.default_rng(0)
    vals = rng.lognormal(mean=-3.0, sigma=1.0, size=1000)
    for v in vals:
        h.observe(v)
    for q in (50, 90, 99):
        assert h.percentile(q) == pytest.approx(np.percentile(vals, q), rel=1e-9)
    assert h.count == 1000
    assert h.sum == pytest.approx(vals.sum())
    s = h.summary()
    assert s["p50"] == pytest.approx(np.percentile(vals, 50))


def test_histogram_bucket_counts_cumulative():
    reg = Registry()
    h = reg.histogram("d", "", buckets=(0.1, 1.0, 10.0))
    for v in (0.05, 0.5, 0.5, 5.0, 50.0):
        h.observe(v)
    rows = h._default().cumulative_buckets()
    assert [(le, n) for le, n in rows] == [
        (0.1, 1), (1.0, 3), (10.0, 4), (float("inf"), 5)
    ]


def test_prometheus_exposition_golden():
    reg = Registry()
    reg.counter("steps_total", "steps done").inc(3)
    reg.gauge("loss", "last loss").set(2.5)
    h = reg.histogram("lat_seconds", "latency", ("phase",), buckets=(0.1, 1.0))
    h.labels(phase="decode").observe(0.05)
    h.labels(phase="decode").observe(0.5)
    expected = "\n".join([
        "# HELP lat_seconds latency",
        "# TYPE lat_seconds histogram",
        'lat_seconds_bucket{phase="decode",le="0.1"} 1',
        'lat_seconds_bucket{phase="decode",le="1"} 2',
        'lat_seconds_bucket{phase="decode",le="+Inf"} 2',
        'lat_seconds_sum{phase="decode"} 0.55',
        'lat_seconds_count{phase="decode"} 2',
        "# HELP loss last loss",
        "# TYPE loss gauge",
        "loss 2.5",
        "# HELP steps_total steps done",
        "# TYPE steps_total counter",
        "steps_total 3",
    ]) + "\n"
    assert reg.to_prometheus() == expected


def test_json_exposition_round_trips_snapshot():
    reg = Registry()
    reg.counter("c", "", ("k",)).labels(k="a").inc(2)
    reg.histogram("h", "").observe(0.2)
    snap = json.loads(reg.to_json())
    assert snap["counters"]["c"] == {'{k="a"}': 2.0}
    assert snap["histograms"]["h"][""]["count"] == 1
    assert snap == json.loads(json.dumps(reg.snapshot(), sort_keys=True))


def test_disabled_mode_is_noop_and_near_free():
    reg = Registry()
    c = reg.counter("c", "")
    h = reg.histogram("h", "")
    set_enabled(False)
    c.inc()
    h.observe(1.0)
    assert c.value == 0 and h.count == 0  # true no-op

    n = 100_000
    t0 = time.perf_counter()
    for _ in range(n):
        c.inc()
        h.observe(1.0)
    disabled = time.perf_counter() - t0
    # Guarded-early-return cost: generous CI bound, ~50x slack over the
    # observed per-call time.
    assert disabled / (2 * n) < 5e-6, f"disabled path too slow: {disabled:.3f}s"
    set_enabled(True)
    c.inc()
    assert c.value == 1


# ---------------------------------------------------------------------------
# tracing
# ---------------------------------------------------------------------------


def test_chrome_trace_schema_valid(tmp_path):
    tr = Tracer(process_name="test")
    with tr.span("outer", cat="t", tid=1, args={"k": 1}):
        with tr.span("inner", cat="t", tid=1):
            pass
    tr.instant("marker", tid=1, args={"rid": 7})
    tr.complete("retro", 0.001, 0.002, tid=2)
    tr.thread_name(1, "slot 1")
    path = tr.save(str(tmp_path / "trace.json"))

    with open(path) as f:
        doc = json.load(f)  # loadable JSON — what Perfetto requires
    evs = doc["traceEvents"]
    assert isinstance(evs, list) and len(evs) >= 5
    for ev in evs:
        assert {"ph", "name", "pid", "tid"} <= set(ev)
    spans = [e for e in evs if e["ph"] == "X"]
    assert len(spans) == 3
    for s in spans:
        assert s["dur"] >= 0 and s["ts"] >= 0
    inner = next(e for e in spans if e["name"] == "inner")
    outer = next(e for e in spans if e["name"] == "outer")
    # Nesting: inner lies within outer on the same lane.
    assert outer["ts"] <= inner["ts"]
    assert inner["ts"] + inner["dur"] <= outer["ts"] + outer["dur"] + 1e-3
    (instant,) = [e for e in evs if e["ph"] == "i"]
    assert instant["args"]["rid"] == 7


# ---------------------------------------------------------------------------
# MFU vs systolic_model at the paper point
# ---------------------------------------------------------------------------


def test_paper_ideal_matches_systolic_model():
    # peak: 2 * 128^2 MACs/cycle at 1.5 GHz
    assert PAPER_ARRAY.peak_flops_per_s == pytest.approx(49.152e12)
    for seq in systolic_model.PAPER_SEQLENS:
        util = systolic_model.fsa_utilization(seq, 128)
        assert paper_ideal_flops_per_s(seq) == pytest.approx(
            util * PAPER_ARRAY.peak_flops_per_s
        )


def test_mfu_meter_achieving_ideal_reads_one():
    """If a phase achieves exactly the paper-ideal FLOPs/s, the
    achieved/ideal gauge must read 1 (and mfu == Fig. 11 utilization)."""
    cfg = ModelConfig(
        name="hd128", family="dense", num_layers=1, d_model=128,
        num_heads=1, num_kv_heads=1, head_dim=128, d_ff=256,
        vocab_size=256, dtype="float32", remat=False,
    )
    reg = Registry()
    meter = MFUMeter(cfg, reg)
    seq = 4096
    flops = 1e12
    seconds = flops / paper_ideal_flops_per_s(seq)
    rec = meter.record("prefill", flops, seconds, seq_len=seq)
    assert rec["mfu_vs_paper_ideal"] == pytest.approx(1.0)
    assert rec["mfu"] == pytest.approx(systolic_model.fsa_utilization(seq, 128))
    assert reg.get("mfu").labels(phase="prefill").value == pytest.approx(rec["mfu"])


def test_flops_closed_forms_scale_sanely():
    # Param term dominates at tiny context; attention term grows with ctx.
    p = TINY.active_param_count()
    assert prefill_flops(TINY, 8) > 2.0 * p * 8
    assert decode_flops(TINY, [16, 16]) > decode_flops(TINY, [4, 4])
    # Train: 3x the forward cost on params (6 vs 2 FLOPs/param/token).
    assert train_step_flops(TINY, 2, 32) > 3 * prefill_flops(TINY, 32)


# ---------------------------------------------------------------------------
# engine integration
# ---------------------------------------------------------------------------


@pytest.fixture(scope="module")
def tiny_params():
    return init_params(TINY, jax.random.PRNGKey(0))


def _run_wave(params, n_requests=5, max_new=4, tracer=None):
    eng = ServeEngine(
        TINY, params, batch_size=2, max_len=32, prefill_buckets=(16,),
        tracer=tracer,
    )
    rng = np.random.default_rng(0)
    for i in range(n_requests):
        eng.submit(Request(
            rid=i,
            prompt=rng.integers(0, TINY.vocab_size, size=6 + i).astype(np.int32),
            max_new_tokens=max_new,
        ))
    done = eng.run()
    assert len(done) == n_requests
    return eng, done


def test_engine_ttft_tpot_plausible(tiny_params):
    eng, done = _run_wave(tiny_params)
    ttft = eng.registry.get("serve_ttft_seconds")
    tpot = eng.registry.get("serve_tpot_seconds")
    queue = eng.registry.get("serve_queue_wait_seconds")
    # One TTFT + one queue-wait observation per request.
    assert ttft.count == len(done)
    assert queue.count == len(done)
    # One TPOT observation per batched decode step.
    assert tpot.count == eng.stats["decode_steps"]
    # Plausibility: positive, ordered, sub-minute on a tiny model.
    assert 0 < tpot.percentile(50) <= tpot.percentile(99) < 60
    assert 0 < ttft.percentile(50) <= ttft.percentile(99) < 60
    # Queue wait <= TTFT (TTFT includes it) for the median request.
    assert queue.percentile(50) <= ttft.percentile(50)
    # Tokens: every request emitted max_new tokens.
    assert eng.registry.get("serve_tokens_total").value == sum(
        len(r.output) for r in done
    )
    assert eng.registry.get("serve_requests_completed_total").value == len(done)
    # MFU gauges populated for both phases.
    for phase in ("prefill", "decode"):
        assert eng.registry.get("mfu").labels(phase=phase).value > 0
    # Occupancy/batch-utilization within [0, 1].
    assert 0 <= eng.registry.get("serve_slot_occupancy").value <= 1
    butil = eng.registry.get("serve_batch_utilization")
    assert 0 < butil.sum / butil.count <= 1


def test_engine_stats_property_backwards_compatible(tiny_params):
    eng, done = _run_wave(tiny_params, n_requests=3)
    stats = eng.stats
    assert isinstance(stats, dict)
    assert stats["prefill_calls"] == 3
    assert stats["insert_calls"] == 3
    assert stats["decode_steps"] > 0
    # Snapshot semantics: mutating the returned dict is harmless.
    before = dict(eng.stats)
    stats["prefill_calls"] = 999
    assert eng.stats == before


def test_engine_prometheus_dump_has_required_series(tiny_params):
    eng, _ = _run_wave(tiny_params, n_requests=3)
    eng.compile_counts()
    prom = eng.registry.to_prometheus()
    for needle in (
        "serve_ttft_seconds_bucket",
        "serve_tpot_seconds_bucket",
        "serve_queue_wait_seconds_bucket",
        "serve_slot_occupancy",
        'mfu{phase="decode"}',
        'serve_jit_executables{phase="generate"}',
    ):
        assert needle in prom, f"missing {needle}"


def test_engine_trace_lifecycle_spans(tiny_params, tmp_path):
    tr = Tracer()
    eng, done = _run_wave(tiny_params, n_requests=3, tracer=tr)
    doc = json.load(open(tr.save(str(tmp_path / "t.json"))))
    names = [e.get("name") for e in doc["traceEvents"]]
    for phase in ("prefill", "generate", "queued", "decode", "retire"):
        assert phase in names, f"no {phase} events in trace"
    # One retroactive queued+decode span pair per retired request.
    assert names.count("queued") == len(done)
    assert names.count("decode") == len(done)


def test_compile_counter_matches_fixture(tiny_params, jit_recompiles):
    """The library watcher (wired into a registry counter) and the test
    fixture count the same log records — their totals must agree."""
    reg = Registry()
    counter = reg.counter("jit_compiles_total", "")
    with watch_jit_compiles(counter) as lib_watcher:
        _run_wave(tiny_params, n_requests=2)
    assert counter.value == lib_watcher.count == jit_recompiles.count
    assert counter.value > 0  # the wave does compile something


def test_engine_token_equivalence_with_tracer_enabled(tiny_params):
    """Instrumentation must not perturb outputs: the same wave with and
    without a live tracer yields identical tokens."""
    _, plain = _run_wave(tiny_params, n_requests=4)
    _, traced = _run_wave(tiny_params, n_requests=4, tracer=Tracer())
    for a, b in zip(
        sorted(plain, key=lambda r: r.rid), sorted(traced, key=lambda r: r.rid)
    ):
        assert a.output == b.output


# ---------------------------------------------------------------------------
# fault-layer + trainer metrics, JSONL round trip
# ---------------------------------------------------------------------------


def test_fault_counters():
    reg = Registry()
    wd = StepWatchdog(timeout_factor=2.0, warmup_steps=1, registry=reg)
    for _ in range(3):
        wd.start_step()
        wd.end_step()
    assert reg.get("watchdog_heartbeats_total").value == 3
    with pytest.raises(Exception):
        wd.check(1e9)
    assert reg.get("watchdog_stragglers_total").value == 1

    ph = PreemptionHandler(install=False, registry=reg)
    ph.trigger()
    assert ph.requested
    assert reg.get("preemptions_total").value == 1


def test_trainer_metrics_and_jsonl_roundtrip(tmp_path):
    jsonl = tmp_path / "train.metrics.jsonl"
    tcfg = TrainerConfig(
        total_steps=4, ckpt_every=100, ckpt_dir=str(tmp_path / "ckpt"),
        log_every=100, metrics_jsonl=str(jsonl),
    )
    t = Trainer(TINY, ShapeConfig("t", 32, 4, "train"), tcfg)
    state = t.run()
    assert state["step"] == 4

    # Registry: counters/gauges/histograms landed.
    reg = t.registry
    assert reg.get("train_steps_total").value == 4
    assert reg.get("train_tokens_total").value == 4 * 32 * 4
    assert reg.get("train_step_seconds").count == 4
    assert np.isfinite(reg.get("train_loss").value)
    assert reg.get("watchdog_heartbeats_total").value == 4
    assert reg.get("mfu").labels(phase="train").value > 0

    # JSONL stream: one record per step; scrape()'s fast path returns them.
    text = jsonl.read_text()
    records = scrape(text)
    assert len(records) == 4
    assert [r["step"] for r in records] == [1, 2, 3, 4]
    assert records[-1]["loss"] == pytest.approx(state["losses"][-1])
    for r in records:
        assert r["event"] == "train_step"
        assert r["mfu"] > 0 and r["step_s"] > 0

    # Interleaved human log lines don't confuse the fast path.
    noisy = "step 1 loss 5.0 gnorm 1.0 3 ms\n" + text + "not json {\n"
    assert scrape(noisy) == records


def test_scrape_regex_fallback_still_works():
    log = (
        "== yi-9b x train_4k on 8x4 (32 chips) ==\n"
        "lower 1.5s compile 12.0s\n"
        "per-device bytes: 3.25 GiB\n"
    )
    (rec,) = scrape(log)
    assert rec["arch"] == "yi-9b" and rec["chips"] == 32
    assert rec["compile_s"] == 12.0
    assert scrape_dryrun(log) == [rec]
