"""repro.tune — design-space autotuner tests.

Pins the acceptance surface of the subsystem: Table 1 capacity
validation, evaluators reproducing the paper's Fig. 11 / Table 2 /
Table 3 numbers at the paper's design point, mesh-sharded sweeps
(per-device shard counts + equality with the host evaluators), the
deterministic search drivers, Pareto extraction, and the end-to-end
report with its fsa_sim cycle cross-checks.
"""

import dataclasses
import json
import os

if "xla_force_host_platform_device_count" not in os.environ.get("XLA_FLAGS", ""):
    os.environ["XLA_FLAGS"] = (
        os.environ.get("XLA_FLAGS", "") + " --xla_force_host_platform_device_count=8"
    )

import numpy as np
import pytest

from repro.core.fsa_flash import fsa_flash_attention
from repro.tune import (
    PAPER_TARGETS,
    DesignPoint,
    dominates,
    evaluate,
    exact_fit_point,
    grid_space,
    grid_sweep,
    paper_point,
    pareto_front,
    quantized_systolic_attention,
    random_search,
    run_tune,
    render_markdown,
    successive_halving,
    tune_mesh,
    write_report,
)
from repro.tune.design import accum_required_bytes, spad_required_bytes


# ---------------------------------------------------------------------------
# DesignPoint / capacity model
# ---------------------------------------------------------------------------

def test_design_point_frozen_hashable():
    p = paper_point()
    with pytest.raises(dataclasses.FrozenInstanceError):
        p.array_n = 64
    assert len({p, DesignPoint(), DesignPoint(array_n=64)}) == 2


def test_paper_point_is_exact_fit_sram():
    """Table 1: 192 KiB spad / 64 KiB accum are exactly the N=128 working set."""
    p = paper_point()
    assert p.spad_bytes == spad_required_bytes(128) == 192 * 1024
    assert p.accum_bytes == accum_required_bytes(128) == 64 * 1024
    p.validate()


@pytest.mark.parametrize(
    "kwargs",
    [
        dict(spad_kib=191),            # 1 KiB short of the working set
        dict(accum_kib=63),
        dict(array_n=96),              # not a power of two
        dict(pwl_segments=6),
        dict(pwl_segments=128),
        dict(schedule="triple"),
        dict(freq_ghz=10.0),
    ],
)
def test_invalid_points_rejected(kwargs):
    p = DesignPoint(**kwargs)
    assert not p.is_valid()
    with pytest.raises(ValueError):
        p.validate()


def test_exact_fit_point_scales_with_n():
    for n in (32, 64, 256):
        p = exact_fit_point(n)
        p.validate()
        assert p.spad_bytes == spad_required_bytes(n)
        assert p.accum_bytes == accum_required_bytes(n)
        # One KiB less on either SRAM breaks validity.
        assert not dataclasses.replace(p, spad_kib=p.spad_kib - 1).is_valid()
        assert not dataclasses.replace(p, accum_kib=p.accum_kib - 1).is_valid()


# ---------------------------------------------------------------------------
# Evaluators vs the paper's published numbers
# ---------------------------------------------------------------------------

@pytest.fixture(scope="module")
def paper_record():
    return evaluate(paper_point(), accuracy_seq=2048)


def test_paper_point_reproduces_fig11(paper_record):
    assert paper_record["speedup_vs_tpu_v5e"] == pytest.approx(1.77, rel=0.01)
    assert paper_record["speedup_vs_neuron_v2"] == pytest.approx(4.83, rel=0.01)
    assert 0.35 < paper_record["mean_util"] < 0.45


def test_paper_point_reproduces_table3(paper_record):
    assert paper_record["array_um2"] == pytest.approx(
        PAPER_TARGETS["area_total_um2"], rel=1e-6
    )
    assert paper_record["overhead_pct"] == pytest.approx(12.07, abs=0.01)
    # §8.2: the single-direction variant drops the upward-path registers.
    single = evaluate(
        dataclasses.replace(paper_point(), schedule="single_direction"),
        accuracy_seq=256,
    )
    assert single["array_um2"] < paper_record["array_um2"]
    assert single["overhead_pct"] < paper_record["overhead_pct"]
    assert single["mean_util"] < paper_record["mean_util"]


def test_paper_point_reproduces_table2_and_fig12(paper_record):
    # Fig. 12 sharp check at the 8-segment setting.
    assert paper_record["pwl_mre"] == pytest.approx(0.02728, rel=0.05)
    # Table 2 envelope (our sim keeps fp32 partial sums, so absolute errors
    # are below the paper's RTL; the published envelope is the bound).
    assert paper_record["acc_mae"] <= PAPER_TARGETS["table2_mae_envelope"]
    assert paper_record["acc_mre"] <= PAPER_TARGETS["table2_mre_envelope"]
    # Fewer segments must be measurably worse end to end.
    coarse = evaluate(
        dataclasses.replace(paper_point(), pwl_segments=2), accuracy_seq=2048
    )
    assert coarse["acc_mre"] > 2 * paper_record["acc_mre"]
    assert coarse["pwl_mre"] > paper_record["pwl_mre"]


@pytest.mark.parametrize("array_n,seq", [(64, 128), (128, 256)])
def test_accuracy_twin_matches_instruction_sim(array_n, seq):
    """quantized_systolic_attention is the same arithmetic as fsa_sim."""
    rng = np.random.default_rng(5)
    q, k, v = (
        rng.standard_normal((seq, array_n)).astype(np.float16) for _ in range(3)
    )
    twin = quantized_systolic_attention(q, k, v, array_n=array_n, num_segments=8)
    sim = fsa_flash_attention(
        q, k, v, array_n=array_n,
        spad_bytes=spad_required_bytes(array_n),
        accum_bytes=accum_required_bytes(array_n) + 4 * array_n,
    )
    assert np.abs(twin - sim.output).max() < 1e-6


# ---------------------------------------------------------------------------
# Mesh-sharded sweep
# ---------------------------------------------------------------------------

def test_grid_sweep_shards_over_8_devices():
    import jax

    assert len(jax.devices()) == 8, "suite requires the 8-device CPU host"
    points = grid_space(
        array_ns=(64, 128), segments=(4, 8), sram_overs=(1, 2), freqs=(1.0, 1.5)
    )
    assert len(points) == 32
    mesh = tune_mesh()
    res = grid_sweep(points, mesh=mesh, accuracy_seq=256)
    # Every device evaluated exactly its shard of the space.
    assert res.per_device_counts == [4] * 8
    assert sum(res.per_device_counts) == len(points)


def test_grid_sweep_pads_ragged_spaces():
    points = grid_space(array_ns=(64, 128), segments=(4, 8, 16))[:11]
    res = grid_sweep(points, mesh=tune_mesh(), accuracy_seq=256)
    # 11 points pad to 16 rows (2 per device); the pad rows are masked out
    # of the valid counts, which must sum to exactly the real point count.
    assert sum(res.per_device_counts) == 11
    assert all(c <= 2 for c in res.per_device_counts)
    assert len(res.records) == 11


def test_mesh_sweep_matches_host_evaluators():
    """The jnp shard_map evaluator == the scalar host evaluators."""
    points = grid_space(
        array_ns=(64, 128, 256), segments=(4, 8),
        sram_overs=(1, 2), freqs=(1.0, 1.5),
    )
    res = grid_sweep(points, mesh=tune_mesh(), accuracy_seq=256)
    for point, rec in zip(points, res.records):
        host = evaluate(point, accuracy_seq=256)
        for key in (
            "mean_util", "mean_tflops", "total_um2", "overhead_pct",
            "speedup_vs_tpu_v5e", "speedup_vs_neuron_v2",
        ):
            assert rec[key] == pytest.approx(host[key], rel=1e-5), (
                point.label(), key
            )
        # Accuracy is joined from the same cache: bit-identical.
        assert rec["acc_mae"] == host["acc_mae"]
        assert rec["pwl_mre"] == host["pwl_mre"]


def test_sweep_rejects_invalid_points():
    with pytest.raises(ValueError):
        grid_sweep([DesignPoint(spad_kib=1)], accuracy_seq=256)


# ---------------------------------------------------------------------------
# Search drivers
# ---------------------------------------------------------------------------

def test_random_search_deterministic():
    a = random_search(12, seed=3, accuracy_seq=256)
    b = random_search(12, seed=3, accuracy_seq=256)
    c = random_search(12, seed=4, accuracy_seq=256)
    assert len(a.records) == 12
    assert [r["label"] for r in a.records] == [r["label"] for r in b.records]
    assert [r["label"] for r in a.records] != [r["label"] for r in c.records]
    # No duplicate points, all valid by construction.
    assert len({r["label"] for r in a.records}) == 12


def test_successive_halving_promotes_and_refines():
    points = grid_space(
        array_ns=(64, 128), segments=(4, 8), sram_overs=(1, 2)
    )
    res = successive_halving(
        points, seed=0, eta=2, fidelities=(128, 256, 512), mesh=None
    )
    # Two halvings: 16 -> 8 -> 4 survivors, evaluated at the top fidelity.
    assert len(res.records) == len(points) // 4
    assert all(r["acc_seq"] == 512.0 for r in res.records)
    again = successive_halving(
        points, seed=0, eta=2, fidelities=(128, 256, 512), mesh=None
    )
    assert [r["label"] for r in res.records] == [r["label"] for r in again.records]


# ---------------------------------------------------------------------------
# Pareto
# ---------------------------------------------------------------------------

def test_pareto_front_drops_dominated_points():
    recs = [
        {"mean_tflops": 10.0, "total_um2": 5.0, "acc_mre": 0.01},
        {"mean_tflops": 10.0, "total_um2": 6.0, "acc_mre": 0.01},  # dominated
        {"mean_tflops": 12.0, "total_um2": 9.0, "acc_mre": 0.01},
        {"mean_tflops": 9.0, "total_um2": 4.0, "acc_mre": 0.02},
    ]
    front = pareto_front(recs)
    assert front == [0, 2, 3]
    assert dominates(recs[0], recs[1])
    assert not dominates(recs[1], recs[0])


def test_sram_overprovisioning_is_dominated():
    """Extra SRAM costs area and buys nothing -> never on the frontier."""
    points = grid_space(
        array_ns=(128,), schedules=("standard",), segments=(8,), sram_overs=(1, 2)
    )
    res = grid_sweep(points, accuracy_seq=256)
    front = pareto_front(res.records)
    labels = [res.records[i]["label"] for i in front]
    assert len(front) == 1 and "S192+64KiB" in labels[0]


# ---------------------------------------------------------------------------
# Report (the acceptance surface)
# ---------------------------------------------------------------------------

@pytest.fixture(scope="module")
def smoke_report():
    return run_tune("smoke", seed=0, paper_check_seq=512)


def test_report_paper_checks_pass(smoke_report):
    assert smoke_report["paper_checks_ok"], smoke_report["paper_checks"]
    assert smoke_report["paper_point_in_sweep"]
    assert smoke_report["paper_on_frontier"]


def test_report_sim_cross_checks(smoke_report):
    """>= 3 points validated end to end through the instruction-level sim."""
    checks = smoke_report["sim_checks"]
    assert len(checks) >= 3
    assert all(c["cycles_ok"] for c in checks), checks
    assert all(c["mae_ok"] for c in checks), checks
    assert all(c["on_frontier"] for c in checks)
    # Both schedule variants exercised (6N+10 vs 5N+10 timelines).
    assert {c["label"].split("/")[1] for c in checks} == {"1dir", "2dir"}


def test_report_sharded_over_mesh(smoke_report):
    assert smoke_report["mesh_devices"] == 8
    assert sum(smoke_report["per_device_counts"]) == smoke_report["num_points"]


def test_report_deterministic_and_serializable(tmp_path, smoke_report):
    again = run_tune("smoke", seed=0, paper_check_seq=512)
    strip = lambda r: {k: v for k, v in r.items() if k != "records"}  # noqa: E731
    assert json.dumps(strip(smoke_report), sort_keys=True) == json.dumps(
        strip(again), sort_keys=True
    )
    md = tmp_path / "report.md"
    js = tmp_path / "BENCH_tune.json"
    write_report(smoke_report, md_path=str(md), json_path=str(js))
    payload = json.loads(js.read_text())
    assert payload["frontier_size"] == smoke_report["frontier_size"]
    assert "records" not in payload
    text = md.read_text()
    assert paper_point().label() in text
    assert "Pareto frontier" in text


def test_render_markdown_marks_paper_point(smoke_report):
    md = render_markdown(smoke_report)
    assert f"| {paper_point().label()} *" in md
    assert "on the Pareto frontier" in md


def test_paper_preset_is_the_paper_special_case():
    """preset='paper' reduces the sweep to Fig. 11 + Table 2 + Table 3."""
    rep = run_tune("paper", seed=0, mesh=False, paper_check_seq=512)
    assert rep["num_points"] == 1
    assert rep["paper_on_frontier"]
    assert rep["paper_checks_ok"]
    assert rep["sim_checks_ok"]
