"""Token-equivalence harness for the continuous-batching ServeEngine.

The contract under test: whatever mix of prompt lengths, arrival times,
slot evictions and prefill chunking the engine sees, every request's
output tokens must equal an obviously-correct baseline — batch-1,
teacher-forced, one-token-at-a-time greedy decode
(``sequential_greedy_decode``).  This holds exactly (not approximately)
because chunked flash prefill and per-token decode share one attention
dispatch (``repro.models.attention._impl_attention``) and padded lanes
contribute exact zeros to the softmax.

Also pinned here: jit executables are reused across requests — the
generate step compiles once, prefill once per length bucket, and a second
wave of differently-sized prompts compiles nothing new.
"""

import os

if "xla_force_host_platform_device_count" not in os.environ.get("XLA_FLAGS", ""):
    os.environ["XLA_FLAGS"] = (
        os.environ.get("XLA_FLAGS", "") + " --xla_force_host_platform_device_count=8"
    )

import jax  # noqa: E402
import numpy as np  # noqa: E402
import pytest  # noqa: E402

from repro.configs import get_smoke_config  # noqa: E402
from repro.configs.base import ModelConfig  # noqa: E402
from repro.models import init_params  # noqa: E402
from repro.serve import (  # noqa: E402
    Request,
    SamplingConfig,
    ServeEngine,
    sequential_greedy_decode,
)

TINY = ModelConfig(
    name="tiny",
    family="dense",
    num_layers=2,
    d_model=64,
    num_heads=4,
    num_kv_heads=2,
    head_dim=16,
    d_ff=128,
    vocab_size=128,
    mlp_type="swiglu",
    dtype="float32",
    remat=False,
)

MAX_LEN = 64


@pytest.fixture(scope="module")
def params():
    return init_params(TINY, jax.random.PRNGKey(0))


def _prompts(spec, seed=0):
    rng = np.random.default_rng(seed)
    return [
        rng.integers(1, TINY.vocab_size, size=plen).astype(np.int32)
        for plen, _ in spec
    ]


def _reference(params, prompts, spec, eos_id=-1):
    return {
        i: sequential_greedy_decode(
            TINY, params, p, spec[i][1], eos_id=eos_id, max_len=MAX_LEN
        )
        for i, p in enumerate(prompts)
    }


# Three mixed-length schedules: (batch_size, buckets, prefill_chunk,
# [(prompt_len, max_new_tokens), ...]).  Each has more requests than slots
# (forcing retirement + back-fill), prompts spanning several buckets, and
# lengths that are not multiples of the chunk/bucket sizes.
SCHEDULES = [
    (2, (8, 16, 32), None, [(5, 6), (13, 4), (24, 5), (9, 3), (17, 6)]),
    (3, (8, 32), 8, [(3, 8), (30, 2), (11, 5), (8, 4), (21, 7), (4, 1)]),
    (4, (16,), 4, [(16, 5), (2, 5), (7, 5), (12, 5), (1, 5)]),
]


@pytest.mark.parametrize("batch,buckets,chunk,spec", SCHEDULES)
def test_token_equivalence_mixed_schedules(params, batch, buckets, chunk, spec):
    prompts = _prompts(spec, seed=hash((batch, chunk)) % 1000)
    ref = _reference(params, prompts, spec)

    eng = ServeEngine(
        TINY, params, batch_size=batch, max_len=MAX_LEN,
        prefill_chunk=chunk, prefill_buckets=buckets,
    )
    for i, p in enumerate(prompts):
        eng.submit(Request(rid=i, prompt=p, max_new_tokens=spec[i][1]))
    done = eng.run()

    assert len(done) == len(spec)
    for r in done:
        assert r.output == ref[r.rid], f"rid {r.rid} diverged"
    # Every request prefilled exactly once, into a reused slot pool.
    assert eng.stats["prefill_calls"] == len(spec)
    assert eng.stats["insert_calls"] == len(spec)


def test_mid_stream_insertion(params):
    """Requests arriving while others are mid-decode join the running batch
    without perturbing anyone's tokens."""
    spec = [(12, 8), (6, 8), (20, 6), (9, 6)]
    prompts = _prompts(spec, seed=42)
    ref = _reference(params, prompts, spec)

    eng = ServeEngine(
        TINY, params, batch_size=2, max_len=MAX_LEN, prefill_buckets=(8, 16, 32)
    )
    for i in (0, 1):
        eng.submit(Request(rid=i, prompt=prompts[i], max_new_tokens=spec[i][1]))
    for _ in range(3):  # partially decode the first wave
        eng.step()
    for i in (2, 3):  # late arrivals
        eng.submit(Request(rid=i, prompt=prompts[i], max_new_tokens=spec[i][1]))
    done = eng.run()

    assert len(done) == 4
    for r in done:
        assert r.output == ref[r.rid], f"rid {r.rid} diverged"


def test_slot_eviction_and_backfill(params):
    """A slot whose request hits max_new_tokens retires and is re-used by
    the next queued request within the same step."""
    spec = [(4, 2), (4, 2), (4, 2), (4, 2), (4, 2)]
    prompts = _prompts(spec, seed=7)
    ref = _reference(params, prompts, spec)

    eng = ServeEngine(TINY, params, batch_size=2, max_len=MAX_LEN,
                      prefill_buckets=(8,))
    for i, p in enumerate(prompts):
        eng.submit(Request(rid=i, prompt=p, max_new_tokens=2))
    done = eng.run()
    assert len(done) == 5
    for r in done:
        assert r.output == ref[r.rid]
    # 5 requests through 2 slots: at least one slot served >= 3 requests,
    # so the cache was overwritten in place (not grown).
    assert eng.stats["prefill_calls"] == 5
    assert eng.batch == 2


def test_eos_truncates_and_matches_reference(params):
    prompt = _prompts([(10, 8)], seed=3)[0]
    base = sequential_greedy_decode(TINY, params, prompt, 8, max_len=MAX_LEN)
    eos = base[3]  # force a mid-stream EOS
    ref = sequential_greedy_decode(
        TINY, params, prompt, 8, eos_id=eos, max_len=MAX_LEN
    )
    assert len(ref) < len(base)

    eng = ServeEngine(TINY, params, batch_size=2, max_len=MAX_LEN,
                      prefill_buckets=(16,))
    eng.submit(Request(rid=0, prompt=prompt, max_new_tokens=8, eos_id=eos))
    (r,) = eng.run()
    assert r.output == ref


def test_generate_compiles_once_per_bucket(params, jit_recompiles):
    """Prefill compiles once per bucket, generate exactly once; a second
    wave of new prompt lengths (same buckets) compiles nothing."""
    eng = ServeEngine(TINY, params, batch_size=2, max_len=MAX_LEN,
                      prefill_buckets=(8, 16))
    wave1 = [(5, 3), (8, 3), (12, 3), (16, 3)]  # both buckets, both edges
    for i, p in enumerate(_prompts(wave1, seed=1)):
        eng.submit(Request(rid=i, prompt=p, max_new_tokens=3))
    eng.run()
    counts = eng.compile_counts()
    assert counts["prefill"] == 2  # == number of buckets touched
    assert counts["insert"] == 2  # one per distinct prefix shape
    assert counts["generate"] == 1  # shared by every slot state

    jit_recompiles.reset()
    wave2 = [(7, 4), (3, 2), (13, 5), (9, 3)]  # new lengths, same buckets
    for i, p in enumerate(_prompts(wave2, seed=2)):
        eng.submit(Request(rid=10 + i, prompt=p, max_new_tokens=wave2[i][1]))
    done = eng.run()
    assert len(done) == 4
    assert jit_recompiles.count == 0, "second wave must reuse all executables"
    assert eng.compile_counts() == counts


def test_chunked_prefill_matches_unchunked(params):
    spec = [(24, 6), (17, 6)]
    prompts = _prompts(spec, seed=11)

    outs = []
    for chunk in (None, 8):
        eng = ServeEngine(TINY, params, batch_size=2, max_len=MAX_LEN,
                          prefill_chunk=chunk, prefill_buckets=(32,))
        for i, p in enumerate(prompts):
            eng.submit(Request(rid=i, prompt=p, max_new_tokens=6))
        outs.append(sorted((r.rid, tuple(r.output)) for r in eng.run()))
    assert outs[0] == outs[1]


def test_hybrid_family_scan_prefill(params):
    """Recurrent-state families can't chunk flash prefill; they teacher-force
    under one lax.scan — still one jit call per request, still
    token-equivalent (per-slot state freeze keeps pad tokens out of the
    recurrence)."""
    cfg = get_smoke_config("zamba2-1.2b")
    assert cfg.family == "hybrid"
    hparams = init_params(cfg, jax.random.PRNGKey(0))
    rng = np.random.default_rng(5)
    spec = [(4, 5), (11, 4), (7, 5)]
    prompts = [
        rng.integers(1, cfg.vocab_size, size=n).astype(np.int32) for n, _ in spec
    ]
    ref = {
        i: sequential_greedy_decode(cfg, hparams, p, spec[i][1], max_len=32)
        for i, p in enumerate(prompts)
    }
    eng = ServeEngine(cfg, hparams, batch_size=2, max_len=32,
                      prefill_buckets=(8, 16))
    for i, p in enumerate(prompts):
        eng.submit(Request(rid=i, prompt=p, max_new_tokens=spec[i][1]))
    done = eng.run()
    assert len(done) == 3
    for r in done:
        assert r.output == ref[r.rid]
    assert eng.stats["prefill_calls"] == 3


def _run_sampled(params, prompts, sampling):
    eng = ServeEngine(TINY, params, batch_size=2, max_len=MAX_LEN,
                      prefill_buckets=(16,), sampling=sampling)
    for i, p in enumerate(prompts):
        eng.submit(Request(rid=i, prompt=p, max_new_tokens=5))
    return sorted((r.rid, tuple(r.output)) for r in eng.run())


def test_sampling_deterministic_per_seed(params):
    prompts = _prompts([(6, 5), (12, 5)], seed=9)
    a = _run_sampled(params, prompts, SamplingConfig(temperature=0.8, top_k=5, seed=7))
    b = _run_sampled(params, prompts, SamplingConfig(temperature=0.8, top_k=5, seed=7))
    c = _run_sampled(params, prompts, SamplingConfig(temperature=0.8, top_k=5, seed=8))
    assert a == b  # same seed, same tokens
    assert a != c  # seed actually threads through


def test_top_k_one_equals_greedy(params):
    prompts = _prompts([(6, 5), (12, 5)], seed=9)
    greedy = _run_sampled(params, prompts, SamplingConfig())
    k1 = _run_sampled(params, prompts, SamplingConfig(temperature=0.5, top_k=1))
    assert greedy == k1


def test_top_p_tiny_equals_greedy(params):
    prompts = _prompts([(6, 5), (12, 5)], seed=9)
    greedy = _run_sampled(params, prompts, SamplingConfig())
    p_tiny = _run_sampled(
        params, prompts, SamplingConfig(temperature=0.7, top_p=1e-6)
    )
    assert greedy == p_tiny  # nucleus keeps at least the argmax token


def test_overlong_prompt_rejected(params):
    eng = ServeEngine(TINY, params, batch_size=2, max_len=32,
                      prefill_buckets=(8, 16))
    with pytest.raises(ValueError, match="exceeds the largest prefill bucket"):
        eng.submit(Request(rid=0, prompt=np.zeros(17, np.int32)))


def test_encoder_family_rejected():
    cfg = get_smoke_config("hubert-xlarge")
    with pytest.raises(AssertionError, match="no decode phase"):
        ServeEngine(cfg, params=None)
