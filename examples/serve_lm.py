"""Serving example: batched request engine over prefill + KV-cache decode.

A small dense LM serves a queue of batched requests; prefill uses the
SystolicAttention path (the compute-bound phase the paper accelerates),
decode uses the memory-bound cache path (paper §8.3: FSA is *not* used for
decode).  Greedy decoding of an overfit pattern verifies end-to-end
correctness.

Run:  PYTHONPATH=src python examples/serve_lm.py
"""

import jax
import numpy as np

from repro.configs.base import ModelConfig
from repro.models import init_params
from repro.serve.engine import Request, ServeEngine

CFG = ModelConfig(
    name="demo-serve",
    family="dense",
    num_layers=4,
    d_model=256,
    num_heads=4,
    num_kv_heads=2,
    head_dim=64,
    d_ff=1024,
    vocab_size=512,
    mlp_type="swiglu",
    dtype="float32",
    remat=False,
)


def main():
    params = init_params(CFG, jax.random.PRNGKey(0))
    engine = ServeEngine(CFG, params, batch_size=4, max_len=64)

    rng = np.random.default_rng(0)
    prompts = [rng.integers(0, CFG.vocab_size, size=12).astype(np.int32) for _ in range(8)]
    for i, p in enumerate(prompts):
        engine.submit(Request(rid=i, prompt=p, max_new_tokens=8))

    done = engine.run()
    assert len(done) == 8, f"expected 8 completions, got {len(done)}"
    for r in sorted(done, key=lambda r: r.rid):
        print(f"req {r.rid}: prompt[:4]={r.prompt[:4].tolist()} -> out={r.output}")
        assert len(r.output) == 8

    # Determinism: the same prompt yields the same greedy continuation.
    e2 = ServeEngine(CFG, params, batch_size=4, max_len=64)
    e2.submit(Request(rid=99, prompt=prompts[0], max_new_tokens=8))
    (r2,) = e2.run()
    match = r2.output == sorted(done, key=lambda r: r.rid)[0].output
    print("greedy determinism across batching:", match)
    assert match


if __name__ == "__main__":
    main()
