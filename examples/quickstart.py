"""Quickstart: the paper's technique in three views.

1. SystolicAttention as a drop-in JAX attention (exact vs PWL-exp2 numerics).
2. The FSA device simulator running the paper's Listing-2 kernel with
   cycle-exact §3.5 timing.
3. A tiny transformer using the technique end to end (one train step).

Run:  PYTHONPATH=src python examples/quickstart.py
"""

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import figure11, systolic_attention, naive_attention
from repro.core.fsa_flash import fsa_flash_attention
from repro.core.systolic_model import fsa_attention_cycles


def main():
    # 1. SystolicAttention as a JAX function ------------------------------
    key = jax.random.PRNGKey(0)
    q = jax.random.normal(key, (1, 256, 4, 64))
    k = jax.random.normal(jax.random.fold_in(key, 1), (1, 256, 2, 64))  # GQA
    v = jax.random.normal(jax.random.fold_in(key, 2), (1, 256, 2, 64))
    exact = systolic_attention(q, k, v, causal=True)
    pwl = systolic_attention(q, k, v, causal=True, exp2_impl="pwl")
    ref = naive_attention(q, k, v, causal=True)
    print(f"[1] exact-exp2 max err vs oracle: {float(jnp.abs(exact - ref).max()):.2e}")
    print(f"    PWL-exp2  max err vs oracle: {float(jnp.abs(pwl - ref).max()):.2e} "
          "(paper Table 2 envelope)")

    # 2. FSA device simulator (paper §4-5) ---------------------------------
    rng = np.random.default_rng(0)
    seq, d = 512, 128
    qs, ks, vs = (rng.standard_normal((seq, d)).astype(np.float16) for _ in range(3))
    res = fsa_flash_attention(qs, ks, vs)
    print(f"[2] FSA sim: {res.instr_count} instructions, {res.cycles} cycles "
          f"(closed form 5N+10 model: {fsa_attention_cycles(seq)}) "
          f"= {res.seconds() * 1e6:.1f} us at 1.5 GHz")

    # 3. Fig. 11 reproduction ----------------------------------------------
    fig = figure11()
    print(f"[3] Fig.11 mean utilization: FSA {fig['mean_fsa']:.3f} | "
          f"TPUv5e {fig['mean_tpu_v5e']:.3f} | Neuron-v2 {fig['mean_neuron_v2']:.3f}")
    print(f"    speedups {fig['speedup_vs_tpu_v5e']:.2f}x / "
          f"{fig['speedup_vs_neuron_v2']:.2f}x (paper: 1.77x / 4.83x)")


if __name__ == "__main__":
    main()
