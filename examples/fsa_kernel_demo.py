"""Paper Listing 2, verbatim shape: a custom FlashAttention kernel written
against the FSA Python programming model (§5) and executed on the
instruction-level device simulator with §3.5 cycle accounting.

Run:  PYTHONPATH=src python examples/fsa_kernel_demo.py
"""

import numpy as np

import repro.core.fsa_kernel_api as F
from repro.core.systolic_model import fsa_attention_cycles


def main():
    seq, d = 512, 128
    br = bc = 128
    scale = 1.0 / np.sqrt(d)
    rng = np.random.default_rng(0)
    Q = rng.standard_normal((seq, d)).astype(np.float16)
    K = rng.standard_normal((seq, d)).astype(np.float16)
    V = rng.standard_normal((seq, d)).astype(np.float16)
    Vt_host = np.ascontiguousarray(V.T)  # host-side pre-transpose (§5.3)

    # Accumulation SRAM holds one fp32 O tile + the log-expsum row
    # (128*128*4 + 128*4 = 64 KiB + 512 B; Table 1 rounds to 64 KiB).
    @F.kernel(device="fsa_sim", accum_bytes=d * br * 4 + br * 4)
    def attention(Qm: F.MTile, Km: F.MTile, Vt: F.MTile) -> F.MTile:
        Ot = F.alloc_mem((d, seq), np.float32, name="Ot")
        Ot_tiles = Ot.split(br, dim=-1)
        Q_tiles = Qm.split(br, dim=-2)
        K_tiles = Km.split(bc, dim=-2)
        Vt_tiles = Vt.split(bc, dim=-1)

        Q_s = (F.alloc_spad((br, d)), F.alloc_spad((br, d)))
        K_s = (F.alloc_spad((bc, d)), F.alloc_spad((bc, d)))
        V_s = (F.alloc_spad((d, bc)), F.alloc_spad((d, bc)))
        log_expsum = F.alloc_accum((1, br))
        O_acc = F.alloc_accum((d, br))

        for i, Q_i in enumerate(Q_tiles):
            F.load_tile(Q_i, Q_s[i % 2])
            dev = F._ctx().device
            O_acc._write(dev.accum, np.zeros(O_acc.shape, np.float32))
            log_expsum._write(dev.accum, np.zeros(log_expsum.shape, np.float32))
            for j, (K_j, Vt_j) in enumerate(zip(K_tiles, Vt_tiles)):
                F.load_stationary(Q_s[i % 2], transpose=True, reset_stats=(j == 0))
                F.load_tile(K_j, K_s[j % 2])
                F.attn_score(K_s[j % 2], log_expsum, scale=scale)
                F.load_tile(Vt_j, V_s[j % 2])
                F.attn_value(V_s[j % 2], O_acc)
            F.reciprocal(log_expsum)
            F.attn_lse_norm(O_acc)
            F.store_tile(O_acc, Ot_tiles[i])
        return Ot

    res = attention(Q, K, Vt_host)
    O = res.output.T  # host-side transpose back

    # Exact reference.
    s = Q.astype(np.float64) @ K.astype(np.float64).T * scale
    p = np.exp(s - s.max(-1, keepdims=True))
    p /= p.sum(-1, keepdims=True)
    ref = p @ V.astype(np.float64)

    print(f"instructions: {res.instr_count}   cycles: {res.cycles} "
          f"(5N+10 model: {fsa_attention_cycles(seq)})")
    print(f"MAE vs exact SDPA: {np.abs(O - ref).mean():.2e}")
    print("program head:", res.program.instrs[:6])


if __name__ == "__main__":
    main()
