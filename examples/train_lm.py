"""End-to-end driver: train a ~100M-param dense LM for a few hundred steps
with the full production stack — SystolicAttention layers, AdamW + cosine,
deterministic data pipeline, async atomic checkpointing, watchdog — and
demonstrate crash-recovery by killing and resuming mid-run.

Run:  PYTHONPATH=src python examples/train_lm.py [--steps 300]
"""

import argparse
import dataclasses
import tempfile

from repro.configs.base import ModelConfig, ShapeConfig
from repro.train.trainer import Trainer, TrainerConfig

# ~100M params: 12L x d=768 x ff=3072, vocab 32k, tied embeddings.
CFG_100M = ModelConfig(
    name="demo-100m",
    family="dense",
    num_layers=12,
    d_model=768,
    num_heads=12,
    num_kv_heads=12,
    head_dim=64,
    d_ff=3072,
    vocab_size=32000,
    mlp_type="swiglu",
    tie_embeddings=True,
    dtype="float32",
    remat=False,
    attn_block_q=128,
    attn_block_k=128,
)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=256)
    ap.add_argument("--ckpt-dir", default=None)
    args = ap.parse_args()

    shape = ShapeConfig("demo", args.seq, args.batch, "train")
    ckpt_dir = args.ckpt_dir or tempfile.mkdtemp(prefix="repro_train_")
    tcfg = TrainerConfig(
        total_steps=args.steps,
        ckpt_every=50,
        ckpt_dir=ckpt_dir,
        peak_lr=3e-4,
        warmup_steps=20,
        log_every=10,
    )
    trainer = Trainer(CFG_100M, shape, tcfg)

    print(f"training {CFG_100M.param_count()/1e6:.0f}M params, "
          f"{args.steps} steps, ckpts -> {ckpt_dir}")
    state = trainer.run()
    losses = state["losses"]
    print(f"loss: first {losses[0]:.3f} -> last {losses[-1]:.3f}")
    assert losses[-1] < losses[0], "training must make progress"

    # Crash-recovery demo: a fresh Trainer resumes from the latest ckpt.
    resumed = Trainer(CFG_100M, shape, dataclasses.replace(tcfg, total_steps=args.steps + 10))
    state2 = resumed.run()
    print(f"resumed from step {state['step']} -> {state2['step']} OK")


if __name__ == "__main__":
    main()
