"""Speculative decoding: accepted tokens/step, acceptance rate, tok/s.

Runs the serving benchmark model through the engine twice — vanilla
continuous batching and speculative mode (repro.spec) — on identical
request streams, and records:

  * acceptance rate and accepted tokens per verify step;
  * target-model generate steps, vanilla vs speculative — for the
    self-draft sanity config (draft == target) acceptance must be exactly
    1.0 and the target must take >= 1.5x fewer steps;
  * decode tokens/s for both modes (the PR 6 ``BENCH_serve.json`` number
    is the vanilla baseline) plus an int8-quantized-draft variant's
    acceptance rate (the MatrixFlow-style near-free draft).

Outputs are asserted token-identical between the two modes — the lossless
greedy guarantee — and written to ``BENCH_spec.json``; CI uploads it per
commit.
"""

from __future__ import annotations

import json
import time

import jax
import numpy as np

from repro.models import init_params
from repro.serve import Request, ServeEngine
from repro.spec import SpecConfig

from .serve_bench import BATCH, CFG, PROMPT_LEN

MAX_LEN = 128
MAX_NEW = 40
LOOKAHEAD = 4


def _drain(engine, prompts, max_new=MAX_NEW):
    for i, p in enumerate(prompts):
        engine.submit(Request(rid=i, prompt=p, max_new_tokens=max_new))
    t0 = time.perf_counter()
    done = engine.run()
    jax.block_until_ready(engine.cache)
    dt = time.perf_counter() - t0
    toks = sum(len(r.output) for r in done)
    return {r.rid: r.output for r in done}, toks / dt


def _engine(params, spec=None):
    return ServeEngine(
        CFG, params, batch_size=BATCH, max_len=MAX_LEN, prefill_buckets=(32,),
        spec=spec, draft_params=params if spec is not None else None,
    )


def run(csv_rows: list) -> dict:
    params = init_params(CFG, jax.random.PRNGKey(0))
    rng = np.random.default_rng(0)
    prompts = [
        rng.integers(0, CFG.vocab_size, size=PROMPT_LEN).astype(np.int32)
        for _ in range(BATCH)
    ]

    # Warmup drain compiles every executable, then a fresh timed drain
    # measures warm throughput (same engine, executables cached).  Stats
    # counters accumulate across drains, so delta against the warmup.
    vanilla = _engine(params)
    _drain(vanilla, prompts, max_new=4)
    warm_decode = vanilla.stats["decode_steps"]
    out_v, tok_s_v = _drain(vanilla, prompts)
    vanilla_steps = vanilla.stats["decode_steps"] - warm_decode

    spec_cfg = SpecConfig(lookahead=LOOKAHEAD)  # self-draft sanity config
    spec = _engine(params, spec=spec_cfg)
    _drain(spec, prompts, max_new=4)
    warm = dict(spec.stats)
    out_s, tok_s_s = _drain(spec, prompts)
    verify_steps = spec.stats["verify_steps"] - warm["verify_steps"]
    accepted = spec.stats["accepted_tokens"] - warm["accepted_tokens"]
    proposed = spec.stats["proposed_tokens"] - warm["proposed_tokens"]
    acceptance = accepted / max(proposed, 1)
    accepted_per_step = accepted / max(verify_steps, 1)
    emitted = sum(len(o) for o in out_s.values())
    emitted_per_step = emitted / max(verify_steps, 1)

    assert out_s == out_v, "speculative greedy decode diverged from vanilla"
    assert acceptance == 1.0, (
        f"self-draft acceptance {acceptance:.3f} != 1.0"
    )
    step_reduction = vanilla_steps / max(verify_steps, 1)
    assert step_reduction >= 1.5, (
        f"only {step_reduction:.2f}x fewer target steps (< 1.5x)"
    )

    # int8 draft (target stays fp32): lossless by construction, acceptance
    # measures how much quantization costs in agreement.
    q = _engine(params, spec=SpecConfig(lookahead=LOOKAHEAD, draft_quant="int8"))
    out_q, _ = _drain(q, prompts)
    assert out_q == out_v, "int8-draft speculative decode diverged from vanilla"
    q_acceptance = q.acceptance_rate()

    csv_rows.append((
        "spec_decode", 1e6 / max(tok_s_s, 1e-9),
        f"accept={acceptance:.3f};tok_per_verify={emitted_per_step:.2f};"
        f"step_reduction={step_reduction:.2f}x;int8_draft_accept={q_acceptance:.3f}",
    ))

    result = {
        "benchmark": "spec_decode",
        "lookahead": LOOKAHEAD,
        "acceptance_rate": {
            "self_draft": round(acceptance, 4),
            "int8_draft": round(q_acceptance, 4),
        },
        "accepted_tokens_per_verify_step": round(accepted_per_step, 2),
        "emitted_tokens_per_verify_step": round(emitted_per_step, 2),
        "target_generate_steps": {
            "vanilla": vanilla_steps,
            "speculative": verify_steps,
            "reduction_x": round(step_reduction, 2),
        },
        "decode_tokens_per_s": {
            "vanilla": round(tok_s_v, 1),
            "speculative": round(tok_s_s, 1),
        },
        "lossless": True,
        "model": {
            "family": CFG.family,
            "num_layers": CFG.num_layers,
            "d_model": CFG.d_model,
        },
    }
    with open("BENCH_spec.json", "w") as f:
        json.dump(result, f, indent=2)
    return result
