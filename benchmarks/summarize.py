"""Aggregate all ``BENCH_*.json`` files into one markdown table.

Run after ``python -m benchmarks.run``:

  PYTHONPATH=src python -m benchmarks.summarize

Prints the table to stdout and, when ``GITHUB_STEP_SUMMARY`` is set
(inside a GitHub Actions step), appends it there too — so every CI run
shows serve/flash/quant/spec/train throughput on the run page without
downloading the artifact.
"""

from __future__ import annotations

import glob
import json
import os
import sys


def _flatten(obj, prefix=""):
    """Nested dict -> dotted-key scalar rows, insertion-ordered."""
    rows = []
    for k, v in obj.items():
        key = f"{prefix}.{k}" if prefix else k
        if isinstance(v, dict):
            rows.extend(_flatten(v, key))
        elif isinstance(v, (int, float, bool, str)):
            rows.append((key, v))
        elif isinstance(v, list):
            # Lists are detail payloads (e.g. the BENCH_tune.json Pareto
            # frontier); summarize their size, not their contents.
            rows.append((f"{key}.n", len(v)))
    return rows


def summarize(paths: list[str]) -> str:
    lines = ["# Benchmark summary", ""]
    if not paths:
        lines.append("_no BENCH_*.json files found_")
        return "\n".join(lines) + "\n"
    latency_rows = []  # (file, metric, value): surfaced in their own table
    lines += ["| file | metric | value |", "|---|---|---|"]
    for path in sorted(paths):
        with open(path) as f:
            data = json.load(f)
        name = os.path.basename(path)
        for key, val in _flatten(data):
            if key.startswith("model."):  # config echo, not a metric
                continue
            if key.startswith("latency."):
                latency_rows.append((name, key.removeprefix("latency."), val))
                continue
            lines.append(f"| {name} | {key} | {val} |")
    if latency_rows:
        lines += [
            "",
            "## Latency percentiles (repro.obs)",
            "",
            "| file | metric | value |",
            "|---|---|---|",
        ]
        lines += [f"| {n} | {k} | {v} |" for n, k, v in latency_rows]
    return "\n".join(lines) + "\n"


def main() -> None:
    paths = sys.argv[1:] or glob.glob("BENCH_*.json")
    table = summarize(paths)
    print(table)
    step_summary = os.environ.get("GITHUB_STEP_SUMMARY")
    if step_summary:
        with open(step_summary, "a") as f:
            f.write(table)


if __name__ == "__main__":
    main()
