"""Serving throughput: continuous-batching decode tokens/s + latency tails.

First point on the repo's bench trajectory (ROADMAP "Benchmark
trajectory"): a CPU-runnable tiny-model measurement of the engine's
steady-state generate step — full slot pool, executables warm, one batched
decode per step — written to ``BENCH_serve.json`` so CI archives a
comparable number per commit.

Since PR 10 the latency distribution comes from the engine's own
``repro.obs`` registry: TTFT and TPOT percentiles (TTFT — and TPOT's p99 —
include the jit compile, deliberately: that *is* the first-request
experience) and mean batch utilization ride along in the JSON;
``benchmarks/summarize.py`` folds the ``latency.*`` keys into the CI step
summary.
"""

from __future__ import annotations

import json
import time

import jax
import numpy as np

from repro.configs.base import ModelConfig
from repro.models import init_params
from repro.serve import Request, ServeEngine

BATCH = 4
PROMPT_LEN = 24
TIMED_STEPS = 40

CFG = ModelConfig(
    name="serve-bench-tiny",
    family="dense",
    num_layers=4,
    d_model=128,
    num_heads=8,
    num_kv_heads=4,
    head_dim=16,
    d_ff=256,
    vocab_size=512,
    mlp_type="swiglu",
    dtype="float32",
    remat=False,
)


def run(csv_rows: list) -> dict:
    params = init_params(CFG, jax.random.PRNGKey(0))
    engine = ServeEngine(
        CFG, params, batch_size=BATCH, max_len=128, prefill_buckets=(32,)
    )
    rng = np.random.default_rng(0)
    # max_new_tokens large enough that no slot retires inside the timed
    # window — every timed step decodes exactly BATCH tokens.
    for i in range(BATCH):
        engine.submit(Request(
            rid=i,
            prompt=rng.integers(0, CFG.vocab_size, size=PROMPT_LEN).astype(np.int32),
            max_new_tokens=TIMED_STEPS + 8,
        ))
    for _ in range(3):  # warmup: prefill + insert + generate all compile
        engine.step()

    t0 = time.perf_counter()
    for _ in range(TIMED_STEPS):
        engine.step()
    jax.block_until_ready(engine.cache)
    dt = time.perf_counter() - t0

    toks = TIMED_STEPS * BATCH
    tok_s = toks / dt
    us_per_step = dt / TIMED_STEPS * 1e6
    csv_rows.append(
        ("serve_decode", us_per_step, f"decode_tok_s={tok_s:.1f};batch={BATCH}")
    )

    ttft = engine.registry.get("serve_ttft_seconds")
    tpot = engine.registry.get("serve_tpot_seconds")
    butil = engine.registry.get("serve_batch_utilization")
    result = {
        "benchmark": "serve_decode",
        "decode_tokens_per_s": round(tok_s, 1),
        "us_per_generate_step": round(us_per_step, 1),
        "batch_size": BATCH,
        "prompt_len": PROMPT_LEN,
        "timed_steps": TIMED_STEPS,
        "latency": {
            "ttft_p50_ms": round(ttft.percentile(50) * 1e3, 3),
            "ttft_p99_ms": round(ttft.percentile(99) * 1e3, 3),
            "tpot_p50_ms": round(tpot.percentile(50) * 1e3, 3),
            "tpot_p99_ms": round(tpot.percentile(99) * 1e3, 3),
            "batch_utilization_mean": round(butil.sum / max(butil.count, 1), 4),
        },
        "mfu_decode": engine.registry.get("mfu").labels(phase="decode").value,
        "model": {
            "family": CFG.family,
            "num_layers": CFG.num_layers,
            "d_model": CFG.d_model,
            "num_heads": CFG.num_heads,
        },
        "stats": dict(engine.stats),
        "compiles": engine.compile_counts(),
    }
    with open("BENCH_serve.json", "w") as f:
        json.dump(result, f, indent=2)
    return result
