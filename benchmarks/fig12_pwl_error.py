"""Paper Fig. 12: exp2 PWL interpolation error vs segment count, exhaustive
over all negative normal fp16 values.  Paper's 8-segment point: MAE 0.00014,
MRE 0.02728."""

from __future__ import annotations

import time

from repro.core.pwl_exp2 import pwl_error_stats


def run(csv_rows: list) -> dict:
    out = {}
    for k in (2, 4, 8, 16, 32, 64):
        t0 = time.perf_counter()
        stats = pwl_error_stats(k)
        us = (time.perf_counter() - t0) * 1e6
        out[k] = stats
        csv_rows.append(
            (f"fig12_segments{k}", us, f"mae={stats['mae']:.3e};mre={stats['mre']:.4f}")
        )
    # Paper-claim checks at 8 segments.
    s8 = out[8]
    assert abs(s8["mae"] - 1.4e-4) / 1.4e-4 < 0.1, s8
    assert abs(s8["mre"] - 0.02728) / 0.02728 < 0.05, s8
    return out
