"""Design-space autotune sweep (repro.tune): Pareto report + BENCH JSON.

Runs the mesh-sharded smoke sweep end to end — grid over array size,
schedule variant and PWL segment count, Pareto frontier over (TFLOP/s,
area, Table 2 error) — and asserts the subsystem's cross-checks:

  * the paper's design point reproduces Fig. 11 / Table 2 / Table 3
    (speedups 1.77x / 4.83x, array area 28,157,816 um^2, 12.07% overhead,
    PWL MRE 2.728e-2 at 8 segments) and sits on the Pareto frontier;
  * >= 3 frontier points validate through the instruction-level fsa_sim
    (cycle counts equal the §3.5 closed forms, MAE inside the Table 2
    envelope).

Writes ``tune_report.md`` (the regenerable Pareto report) and
``BENCH_tune.json``; CI uploads both per commit.
"""

from __future__ import annotations

import os
import sys
import time


def run(csv_rows: list) -> dict:
    # The sweep shards over the local mesh; on a CPU host ask XLA for 8
    # virtual devices (same as tests/conftest.py).  Only possible before
    # jax initializes — under ``--only tune`` this module runs first.
    if "jax" not in sys.modules and "xla_force_host_platform_device_count" not in os.environ.get("XLA_FLAGS", ""):
        os.environ["XLA_FLAGS"] = (
            os.environ.get("XLA_FLAGS", "")
            + " --xla_force_host_platform_device_count=8"
        )

    from repro.tune import run_tune, write_report

    t0 = time.perf_counter()
    report = run_tune("smoke", seed=0)
    us = (time.perf_counter() - t0) * 1e6

    assert report["paper_checks_ok"], report["paper_checks"]
    assert report["sim_checks_ok"], report["sim_checks"]
    assert report["paper_on_frontier"]
    assert sum(report["per_device_counts"]) == report["num_points"]

    write_report(report, md_path="tune_report.md", json_path="BENCH_tune.json")

    paper = report["paper"]
    csv_rows.append(
        (
            "tune_smoke_sweep",
            us,
            f"points={report['num_points']};frontier={report['frontier_size']};"
            f"devices={report['mesh_devices']}",
        )
    )
    csv_rows.append(
        (
            "tune_paper_point",
            0.0,
            f"speedup_tpu={paper['speedup_vs_tpu_v5e']:.2f}x(paper 1.77x);"
            f"speedup_neuron={paper['speedup_vs_neuron_v2']:.2f}x(paper 4.83x);"
            f"overhead={paper['overhead_pct']:.2f}%(paper 12.07%)",
        )
    )
    for c in report["sim_checks"]:
        csv_rows.append(
            (
                f"tune_sim_check_{c['label']}",
                0.0,
                f"cycles={c['cycles_sim']}(model {c['cycles_model']});"
                f"mae={c['mae']:.2e}",
            )
        )
    return report
