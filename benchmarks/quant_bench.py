"""int8 quantization: decode throughput, KV-cache footprint, fidelity.

Runs the serving benchmark model twice — full-precision and under the int8
policy (``repro.quant``: int8 projections + int8 KV cache) — and records:

  * steady-state decode tokens/s for both engines;
  * KV-cache bytes per slot (the int8 cache must be >= 3x smaller);
  * teacher-forced greedy fidelity of the quantized model against fp32
    (top-1 agreement must be >= 0.95) plus the logit MSE.

Written to ``BENCH_quant.json``; CI uploads it per commit.
"""

from __future__ import annotations

import dataclasses
import json
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.models import forward, init_params
from repro.models.model import init_cache
from repro.optim import make_optimizer
from repro.quant import QuantConfig
from repro.serve import Request, ServeEngine
from repro.train.train_step import make_train_step

from .serve_bench import BATCH, CFG, PROMPT_LEN, TIMED_STEPS

MAX_LEN = 128
MIN_CACHE_RATIO = 3.0
MIN_TOP1_AGREEMENT = 0.95
FIT_STEPS = 60


def _sequences(key, n: int, s: int) -> jax.Array:
    """Deterministic affine next-token sequences: x[t+1] = (5x[t]+17) % V."""
    start = jax.random.randint(key, (n, 1), 0, CFG.vocab_size)

    def step(x, _):
        nxt = (5 * x + 17) % CFG.vocab_size
        return nxt, nxt

    _, rest = jax.lax.scan(step, start, None, length=s - 1)
    return jnp.concatenate([start, rest[:, :, 0].T], axis=1)


def _fit_params(params):
    """A few training steps on the affine-sequence task, so the fidelity
    measurement runs on peaked (trained) logits.  Random-init logits are
    near-uniform and the greedy argmax there is decided by noise — it
    measures tie-breaking, not quantization quality."""
    opt = make_optimizer("adamw", lr=1e-3)
    step = jax.jit(make_train_step(CFG, opt))
    opt_state = opt.init(params)
    toks = _sequences(jax.random.PRNGKey(2), 32, 48)
    batch = {"tokens": toks, "labels": jnp.concatenate(
        [toks[:, 1:], jnp.full((toks.shape[0], 1), -1, toks.dtype)], axis=1
    )}
    for _ in range(FIT_STEPS):
        params, opt_state, metrics = step(params, opt_state, batch)
    return params, float(metrics["loss"])


def _decode_tok_s(cfg, params) -> float:
    engine = ServeEngine(
        cfg, params, batch_size=BATCH, max_len=MAX_LEN, prefill_buckets=(32,)
    )
    rng = np.random.default_rng(0)
    for i in range(BATCH):
        engine.submit(Request(
            rid=i,
            prompt=rng.integers(0, cfg.vocab_size, size=PROMPT_LEN).astype(np.int32),
            max_new_tokens=TIMED_STEPS + 8,
        ))
    for _ in range(3):
        engine.step()
    t0 = time.perf_counter()
    for _ in range(TIMED_STEPS):
        engine.step()
    jax.block_until_ready(engine.cache)
    return TIMED_STEPS * BATCH / (time.perf_counter() - t0)


def _cache_bytes(cfg) -> int:
    cache = init_cache(cfg, 1, MAX_LEN)
    return sum(leaf.size * leaf.dtype.itemsize for leaf in jax.tree.leaves(cache))


def run(csv_rows: list) -> dict:
    params = init_params(CFG, jax.random.PRNGKey(0))
    qcfg = dataclasses.replace(CFG, quant=QuantConfig())
    params, fit_loss = _fit_params(params)

    # Fidelity: teacher-forced forward over held-out sequences — does the
    # quantized model pick the same greedy token?  (Robust against the
    # trajectory divergence a free-running decode comparison would measure.)
    toks = _sequences(jax.random.PRNGKey(3), 8, 48)
    logits_fp = forward(params, CFG, tokens=toks)
    logits_q = forward(params, qcfg, tokens=toks)
    agreement = float(
        (jnp.argmax(logits_q, -1) == jnp.argmax(logits_fp, -1)).mean()
    )
    mse = float(jnp.mean(jnp.square(logits_q - logits_fp)))

    fp_bytes = _cache_bytes(CFG)
    q_bytes = _cache_bytes(qcfg)
    ratio = fp_bytes / q_bytes

    tok_s_fp = _decode_tok_s(CFG, params)
    tok_s_q = _decode_tok_s(qcfg, params)

    assert ratio >= MIN_CACHE_RATIO, (
        f"int8 KV cache only {ratio:.2f}x smaller (< {MIN_CACHE_RATIO}x)"
    )
    assert agreement >= MIN_TOP1_AGREEMENT, (
        f"greedy top-1 agreement {agreement:.3f} < {MIN_TOP1_AGREEMENT}"
    )

    csv_rows.append((
        "quant_decode", 1e6 * BATCH / tok_s_q,
        f"tok_s_int8={tok_s_q:.1f};tok_s_fp32={tok_s_fp:.1f};"
        f"cache_ratio={ratio:.2f};top1={agreement:.3f}",
    ))

    result = {
        "benchmark": "quant_serve",
        "decode_tokens_per_s": {
            "fp32": round(tok_s_fp, 1),
            "int8": round(tok_s_q, 1),
        },
        "kv_cache_bytes_per_slot": {
            "fp32": fp_bytes,
            "int8": q_bytes,
            "reduction_x": round(ratio, 2),
        },
        "fidelity": {
            "greedy_top1_agreement": round(agreement, 4),
            "logit_mse": mse,
            "fit_loss": round(fit_loss, 4),
            "fit_steps": FIT_STEPS,
        },
        "model": {
            "family": CFG.family,
            "num_layers": CFG.num_layers,
            "d_model": CFG.d_model,
            "head_dim": CFG.resolved_head_dim,
        },
    }
    with open("BENCH_quant.json", "w") as f:
        json.dump(result, f, indent=2)
    return result
