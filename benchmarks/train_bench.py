"""Training throughput: steps/s and tokens/s on a tiny dense config.

Jits ``make_train_step`` (AdamW, single microbatch) on the serving
benchmark model, drives it with a fixed synthetic token batch, and times
warm steps only — compile happens in the warmup.  Emits
``BENCH_train.json`` so CI tracks train-step throughput per commit
alongside the serve/quant/spec numbers.
"""

from __future__ import annotations

import json
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.models import init_params
from repro.optim import AdamW
from repro.train.train_step import make_train_step

from .serve_bench import CFG

BATCH = 8
SEQ_LEN = 64
WARMUP = 2
STEPS = 10


def run(csv_rows: list) -> dict:
    params = init_params(CFG, jax.random.PRNGKey(0))
    opt = AdamW(lr=1e-3)
    opt_state = opt.init(params)
    step_fn = jax.jit(make_train_step(CFG, opt))

    rng = np.random.default_rng(0)
    toks = rng.integers(0, CFG.vocab_size, (BATCH, SEQ_LEN))
    batch = {
        "tokens": jnp.asarray(toks, jnp.int32),
        "labels": jnp.asarray(toks, jnp.int32),
    }

    for _ in range(WARMUP):
        params, opt_state, metrics = step_fn(params, opt_state, batch)
    jax.block_until_ready(metrics["loss"])

    t0 = time.perf_counter()
    for _ in range(STEPS):
        params, opt_state, metrics = step_fn(params, opt_state, batch)
    jax.block_until_ready(metrics["loss"])
    dt = time.perf_counter() - t0

    steps_per_s = STEPS / dt
    tokens_per_s = steps_per_s * BATCH * SEQ_LEN
    loss = float(metrics["loss"])
    assert np.isfinite(loss), f"non-finite loss {loss} after {STEPS} steps"

    csv_rows.append((
        "train_step", dt / STEPS * 1e6,
        f"steps_per_s={steps_per_s:.2f};tokens_per_s={tokens_per_s:.0f}",
    ))

    result = {
        "benchmark": "train_step",
        "steps_per_s": round(steps_per_s, 2),
        "tokens_per_s": round(tokens_per_s, 1),
        "step_ms": round(dt / STEPS * 1e3, 2),
        "batch_size": BATCH,
        "seq_len": SEQ_LEN,
        "timed_steps": STEPS,
        "final_loss": round(loss, 4),
        "model": {
            "family": CFG.family,
            "num_layers": CFG.num_layers,
            "d_model": CFG.d_model,
        },
    }
    with open("BENCH_train.json", "w") as f:
        json.dump(result, f, indent=2)
    return result
