"""Paper Fig. 11: FlashAttention FLOPs/s utilization — FSA vs TPUv5e vs
NeuronCore-v2, seq 2048..16384, head_dim 128.

Two independent reproductions:
  * the closed-form cycle model (core.systolic_model);
  * the instruction-level FSA simulator (core.fsa_sim) running the paper's
    Listing-2 kernel — cross-checks the 5N+10 schedule end to end.
The paper's headline means: FSA/TPUv5e = 1.77x, FSA/Neuron-v2 = 4.83x.
"""

from __future__ import annotations

import time

import numpy as np

from repro.core.fsa_flash import fsa_flash_attention
from repro.core.systolic_model import (
    attention_flops,
    figure11,
    fsa_utilization,
)


def run(csv_rows: list) -> dict:
    fig = figure11()
    for r in fig["rows"]:
        csv_rows.append(
            (
                f"fig11_seq{r['seq_len']}",
                0.0,
                f"fsa={r['fsa']:.4f};tpu={r['tpu_v5e']:.4f};neuron={r['neuron_v2']:.4f}",
            )
        )

    # Simulator cross-check at a runnable size (seq 1024; the model predicts
    # utilization is within 1% of the 16k asymptote by then).
    seq, d = 1024, 128
    rng = np.random.default_rng(0)
    q, k, v = (rng.standard_normal((seq, d)).astype(np.float16) for _ in range(3))
    t0 = time.perf_counter()
    res = fsa_flash_attention(q, k, v)
    wall = (time.perf_counter() - t0) * 1e6
    sim_util = attention_flops(seq, d) / (res.cycles * 2 * 128 * 128)
    model_util = fsa_utilization(seq, d)
    csv_rows.append(("fig11_sim_vs_model_seq1024", wall,
                     f"sim={sim_util:.4f};model={model_util:.4f}"))
    assert abs(sim_util - model_util) < 1e-9, "simulator != closed-form model"

    csv_rows.append(
        (
            "fig11_mean_speedups",
            0.0,
            f"vs_tpu={fig['speedup_vs_tpu_v5e']:.3f}(paper 1.77);"
            f"vs_neuron={fig['speedup_vs_neuron_v2']:.3f}(paper 4.83)",
        )
    )
    return fig
