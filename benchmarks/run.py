"""Benchmark harness — one module per paper table/figure.

Prints ``name,us_per_call,derived`` CSV rows and asserts the paper's
headline numbers (Fig. 11 speedups, Fig. 12 PWL errors, Table 2 accuracy
envelope, Table 3 area overhead, §3.5 cycle counts).

Also emits machine-readable ``BENCH_*.json`` files into the working
directory — ``BENCH_serve.json`` (continuous-batching decode tokens/s),
``BENCH_flash.json`` (flash attention fwd/bwd FLOPs/s vs references),
``BENCH_quant.json`` (int8 decode throughput, KV-cache footprint and
greedy fidelity), ``BENCH_spec.json`` (speculative decoding acceptance
rate and target-step reduction), ``BENCH_train.json`` (train-step
steps/s and tokens/s) and ``BENCH_tune.json`` (design-space autotune
Pareto frontier + paper cross-checks, with ``tune_report.md``) — CI
uploads them as workflow artifacts so throughput is tracked per commit.

``--only NAME`` (repeatable) runs a subset, e.g.
``python -m benchmarks.run --only tune``.

Roofline terms per (arch x mesh) come from the compiled dry-run
(launch/dryrun.py + launch/roofline.py), not from here — this harness is
CPU-runnable paper-claim reproduction.
"""

from __future__ import annotations

import argparse
import sys
import traceback


def main() -> None:
    from . import (
        fig1_active_time,
        fig11_utilization,
        fig12_pwl_error,
        flash_bench,
        quant_bench,
        section35_cycles,
        serve_bench,
        spec_bench,
        table2_accuracy,
        table3_area,
        train_bench,
        tune_bench,
    )

    modules = [
        ("fig1", fig1_active_time),
        ("fig11", fig11_utilization),
        ("fig12", fig12_pwl_error),
        ("table2", table2_accuracy),
        ("table3", table3_area),
        ("sec35", section35_cycles),
        ("serve", serve_bench),
        ("flash", flash_bench),
        ("quant", quant_bench),
        ("spec", spec_bench),
        ("train", train_bench),
        ("tune", tune_bench),
    ]

    ap = argparse.ArgumentParser(description="paper-claim benchmark harness")
    ap.add_argument(
        "--only",
        action="append",
        metavar="NAME",
        choices=[name for name, _ in modules],
        help="run only the named benchmark(s); repeatable",
    )
    args = ap.parse_args()
    if args.only:
        modules = [(name, mod) for name, mod in modules if name in args.only]

    csv_rows: list[tuple[str, float, str]] = []
    failed = []
    for name, mod in modules:
        try:
            mod.run(csv_rows)
        except Exception:
            traceback.print_exc()
            failed.append(name)

    print("name,us_per_call,derived")
    for row in csv_rows:
        print(f"{row[0]},{row[1]:.1f},{row[2]}")
    if failed:
        print(f"FAILED benchmarks: {failed}", file=sys.stderr)
        raise SystemExit(1)


if __name__ == "__main__":
    main()
