"""Paper Fig. 1: component active-time imbalance on NeuronCore-v2 running
FlashAttention — the paper measures tensor engine ~45% active vs scalar
unit ~80% active (with <25% FLOPs/s utilization even while active).

Our single-knob model is calibrated to *utilization*, so its "array busy"
fraction is the utilization-equivalent lower bound (~9%): the measured 45%
active time additionally includes low-occupancy active cycles (small
tiles / bank conflicts) that a throughput model cannot distinguish from
idle.  What the model does reproduce — and what motivates FSA — is the
*imbalance*: the scalar/vector path is the saturated resource (>=70%
busy) while the matmul array starves.
"""

from __future__ import annotations

import math

from repro.core.systolic_model import ACCELERATORS, matmul_cycles


def active_times(which: str, seq_len: int = 8192, head_dim: int = 128) -> dict:
    m = ACCELERATORS[which]
    bq, bk = min(m.block_q, seq_len), min(m.block_k, seq_len)
    mm_flops = 2.0 * bq * bk * head_dim * 2
    t_mm = mm_flops / m.peak_matmul_flops_per_cycle + matmul_cycles(0, m.array_n)
    t_vec = (m.vector_ops_per_elem * bq * bk) / m.vector_flops_per_cycle
    period = max(t_mm, t_vec) + m.swap_overhead_tiles * m.array_n
    return {
        "array_active_pct": 100.0 * t_mm / period,
        "vector_scalar_active_pct": 100.0 * t_vec / period,
    }


def run(csv_rows: list) -> dict:
    out = {}
    for which in ("neuron_v2", "tpu_v5e"):
        a = active_times(which)
        out[which] = a
        csv_rows.append(
            (
                f"fig1_{which}",
                0.0,
                f"array={a['array_active_pct']:.0f}pct;"
                f"vector_scalar={a['vector_scalar_active_pct']:.0f}pct",
            )
        )
    # Paper Fig. 1 (Neuron-v2): the scalar path saturates while the array
    # starves (paper: 80% vs 45% active at <25% utilization-while-active).
    n = out["neuron_v2"]
    assert n["vector_scalar_active_pct"] >= 70, n
    assert n["array_active_pct"] < n["vector_scalar_active_pct"] / 2, n
    return out
