"""Paper §3.5 / §8.2 cycle-count claims:

  * FSA inner iteration: 5N + 10 cycles per N x N tile;
  * naive two-matmul baseline: up to 8N - 2 cycles;
  * single-direction (area-optimized) variant: 6N + 10;
  * outer-loop rescale: 2N + 20 (negligible vs inner loop).

Verified against the instruction-level simulator, plus the Pallas kernel's
wall-time scaling as a software sanity check (its per-tile work is constant,
so us/tile should be ~flat in seq — the software analogue of the schedule).
"""

from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.fsa_flash import fsa_flash_attention
from repro.core.systolic_model import (
    fsa_attention_cycles,
    fsa_rescale_cycles,
    fsa_tile_cycles,
    naive_tile_cycles,
)
from repro.kernels.flash_attention.kernel import flash_attention_fwd


def run(csv_rows: list) -> dict:
    n = 128
    out = {
        "fsa_tile": fsa_tile_cycles(n),
        "fsa_tile_single_dir": fsa_tile_cycles(n, single_direction=True),
        "naive_tile": naive_tile_cycles(n),
        "rescale": fsa_rescale_cycles(n),
    }
    assert out["fsa_tile"] == 5 * n + 10
    assert out["fsa_tile_single_dir"] == 6 * n + 10
    assert out["naive_tile"] == 8 * n - 2
    csv_rows.append(("sec35_tile_cycles", 0.0,
                     f"fsa={out['fsa_tile']};naive={out['naive_tile']};"
                     f"single_dir={out['fsa_tile_single_dir']}"))

    # Simulator end-to-end == closed form for several sizes.
    rng = np.random.default_rng(0)
    for seq in (256, 512, 1024):
        q, k, v = (rng.standard_normal((seq, 128)).astype(np.float16) for _ in range(3))
        res = fsa_flash_attention(q, k, v)
        expect = fsa_attention_cycles(seq)
        assert res.cycles == expect, (seq, res.cycles, expect)
        csv_rows.append((f"sec35_sim_cycles_seq{seq}", 0.0, f"{res.cycles}"))

    # Pallas kernel software scaling (interpret mode; relative only).
    for seq in (256, 512):
        q = jnp.asarray(rng.standard_normal((1, seq, 1, 128)), jnp.float32)
        k, v = q + 0.1, q + 0.2
        f = lambda: flash_attention_fwd(q, k, v, interpret=True).block_until_ready()  # noqa: E731
        f()
        t0 = time.perf_counter()
        f()
        us = (time.perf_counter() - t0) * 1e6
        tiles = (seq // 128) ** 2
        csv_rows.append((f"sec35_pallas_us_per_tile_seq{seq}", us / tiles, ""))
    return out
