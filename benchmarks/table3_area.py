"""Paper Table 3: FSA area breakdown model.

The paper synthesizes RTL at 16 nm/1.5 GHz; we cannot synthesize, so we
reproduce the *component-count accounting* that produces the 12.07%
overhead: per-unit areas are derived from the paper's own totals and the
known replication factors (N^2 PEs, N^2 split units, N^2 upward-path
registers, N CMP units), then the model re-predicts the overhead for other
array sizes — the scaling claim implicit in the paper's design argument
(CMP row cost amortizes as N grows; per-PE costs do not).
"""

from __future__ import annotations

N = 128
# Paper Table 3 (um^2).
PAPER = {
    "pes": 24_445_044,
    "other": 313_457,
    "upward": 1_756_641,
    "split": 1_493_150,
    "cmp": 149_524,
}


def area_model(n: int) -> dict:
    per_pe = PAPER["pes"] / (N * N)
    per_up = PAPER["upward"] / (N * N)
    per_split = PAPER["split"] / (N * N)
    per_cmp = PAPER["cmp"] / N
    std = per_pe * n * n + PAPER["other"]
    add = per_up * n * n + per_split * n * n + per_cmp * n
    return {
        "standard_um2": std,
        "fsa_additional_um2": add,
        "overhead_pct": 100.0 * add / (std + add),
    }


def run(csv_rows: list) -> dict:
    out = {}
    for n in (64, 128, 256):
        m = area_model(n)
        out[n] = m
        csv_rows.append(
            (f"table3_area_n{n}", 0.0, f"overhead={m['overhead_pct']:.2f}pct")
        )
    # Check the 128-point reproduces the paper's 12.07%.
    assert abs(out[128]["overhead_pct"] - 12.07) < 0.1, out[128]
    return out
