"""Paper Table 2: end-to-end FlashAttention accuracy on FSA vs exact SDPA.

Same input distribution as the paper (FlashAttention-3 accuracy protocol):
    Q, K, V ~ N(0,1) + N(0,100) * Bernoulli(0.001)
head_dim 128, no causal mask.  The paper sweeps seq 2048..16384 on the RTL
simulator; we run the instruction-level simulator at 2048 (minutes, exact
protocol) and the jnp PWL SystolicAttention at the paper's full sweep
(same arithmetic, vectorized).
Paper values: MAE 7.98e-3 @2048 rising to 3.40e-2 @16384; MRE 1.6e-2..7.2e-2.
"""

from __future__ import annotations

import time

import jax.numpy as jnp
import numpy as np

from repro.core.attention import naive_attention, systolic_attention
from repro.core.fsa_flash import fsa_flash_attention

SEQS = (2048, 4096, 6144, 8192)  # paper goes to 16384; runtime-capped here
D = 128


def _draw(rng, shape):
    x = rng.standard_normal(shape) + rng.standard_normal(shape) * 10.0 * (
        rng.random(shape) < 0.001
    )
    return x


def run(csv_rows: list) -> dict:
    rng = np.random.default_rng(0)
    out = {}
    for seq in SEQS:
        q = _draw(rng, (seq, D)).astype(np.float16)
        k = _draw(rng, (seq, D)).astype(np.float16)
        v = _draw(rng, (seq, D)).astype(np.float16)
        qj = jnp.asarray(q, jnp.float32)[None, :, None, :]  # [1, seq, 1, D]
        kj = jnp.asarray(k, jnp.float32)[None, :, None, :]
        vj = jnp.asarray(v, jnp.float32)[None, :, None, :]
        t0 = time.perf_counter()
        approx = systolic_attention(qj, kj, vj, exp2_impl="pwl")[0, :, 0, :]
        us = (time.perf_counter() - t0) * 1e6
        exact = naive_attention(qj, kj, vj)[0, :, 0, :]
        diff = np.asarray(approx, np.float64) - np.asarray(exact, np.float64)
        denom = np.abs(np.asarray(exact, np.float64)) + 1e-9
        stats = {
            "mae": float(np.abs(diff).mean()),
            "rmse": float(np.sqrt((diff**2).mean())),
            "mre": float((np.abs(diff) / denom).mean()),
        }
        out[seq] = stats
        csv_rows.append(
            (
                f"table2_seq{seq}",
                us,
                f"mae={stats['mae']:.3e};rmse={stats['rmse']:.3e};mre={stats['mre']:.3e}",
            )
        )

    # Instruction-level simulator point (fp16 inputs, exact paper pipeline).
    seq = 2048
    q = _draw(rng, (seq, D)).astype(np.float16)
    k = _draw(rng, (seq, D)).astype(np.float16)
    v = _draw(rng, (seq, D)).astype(np.float16)
    t0 = time.perf_counter()
    res = fsa_flash_attention(q, k, v)
    us = (time.perf_counter() - t0) * 1e6
    qf, kf, vf = (a.astype(np.float64) for a in (q, k, v))
    s = qf @ kf.T / np.sqrt(D)
    p = np.exp(s - s.max(-1, keepdims=True))
    p /= p.sum(-1, keepdims=True)
    exact = p @ vf
    mae = float(np.abs(res.output - exact).mean())
    csv_rows.append((f"table2_fsa_sim_seq{seq}", us, f"mae={mae:.3e}(paper 7.98e-3)"))
    out["fsa_sim_2048"] = {"mae": mae}
    return out
