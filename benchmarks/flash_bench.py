"""Flash attention throughput: fwd/bwd FLOPs/s vs the references.

Times the scan-based SystolicAttention (``flash_attention(impl='jnp')`` —
the algorithm the Pallas kernels realize, lowered for whatever backend runs
this) against the materialized-softmax reference and
``jax.nn.dot_product_attention`` across a few causal shapes, forward and
forward+backward.  Emits ``BENCH_flash.json`` so CI archives attention
throughput per commit alongside the serving numbers.
"""

from __future__ import annotations

import json
import time

import jax
import jax.numpy as jnp

from repro.kernels.flash_attention import attention_reference, flash_attention

# (batch, seq, heads, head_dim) — causal self-attention shapes.
SHAPES = [
    (1, 256, 8, 64),
    (1, 512, 8, 64),
    (2, 512, 4, 32),
]
WARMUP = 2
REPS = 5


def _attn_flops(b: int, s: int, h: int, d: int, causal: bool = True) -> float:
    """Matmul FLOPs of one attention forward: QK^T + PV, causal halves it."""
    full = 2 * (2 * b * h * s * s * d)
    return full / 2 if causal else full


def _time(fn, *args) -> float:
    for _ in range(WARMUP):
        jax.block_until_ready(fn(*args))
    t0 = time.perf_counter()
    for _ in range(REPS):
        out = fn(*args)
    jax.block_until_ready(out)
    return (time.perf_counter() - t0) / REPS


def _impls(b, s, h, d):
    def flash_fwd(q, k, v):
        return flash_attention(q, k, v, True)

    def ref_fwd(q, k, v):
        return attention_reference(q, k, v, causal=True)

    def xla_fwd(q, k, v):
        # jax.nn.dot_product_attention wants [B, S, H, d] — same layout.
        return jax.nn.dot_product_attention(q, k, v, is_causal=True)

    return {"flash": flash_fwd, "ref": ref_fwd, "xla": xla_fwd}


def run(csv_rows: list) -> dict:
    results = []
    for b, s, h, d in SHAPES:
        keys = jax.random.split(jax.random.PRNGKey(0), 3)
        q, k, v = (
            jax.random.normal(kk, (b, s, h, d), jnp.float32) for kk in keys
        )
        flops_fwd = _attn_flops(b, s, h, d)
        shape_res = {"shape": {"batch": b, "seq": s, "heads": h, "head_dim": d}}
        for name, fn in _impls(b, s, h, d).items():
            fwd = jax.jit(fn)
            dt_fwd = _time(fwd, q, k, v)

            def loss(q, k, v, fn=fn):
                return jnp.sum(fn(q, k, v))

            bwd = jax.jit(jax.grad(loss, argnums=(0, 1, 2)))
            dt_bwd = _time(bwd, q, k, v)
            # fwd+bwd ~ 3.5x fwd matmul FLOPs (recompute + dq/dk/dv).
            shape_res[name] = {
                "fwd_us": round(dt_fwd * 1e6, 1),
                "fwd_gflops_s": round(flops_fwd / dt_fwd / 1e9, 2),
                "bwd_us": round(dt_bwd * 1e6, 1),
                "bwd_gflops_s": round(3.5 * flops_fwd / dt_bwd / 1e9, 2),
            }
        results.append(shape_res)
        csv_rows.append((
            f"flash_fwd_b{b}s{s}h{h}d{d}",
            shape_res["flash"]["fwd_us"],
            f"gflops_s={shape_res['flash']['fwd_gflops_s']};"
            f"ref_gflops_s={shape_res['ref']['fwd_gflops_s']};"
            f"xla_gflops_s={shape_res['xla']['fwd_gflops_s']}",
        ))

    out = {"benchmark": "flash_attention", "impl": "jnp", "shapes": results}
    with open("BENCH_flash.json", "w") as f:
        json.dump(out, f, indent=2)
    return out
